"""Challenge schedule for the staged pipeline (one draw, all stages).

Every challenge is drawn from the shared Fiat-Shamir transcript in a
fixed order; the prover and the standalone verifier call the same
``draw`` classmethods at the same transcript positions.

With heterogeneous layer shapes there is no single (row, col) split any
more: each relation family draws ONE global element point spanning its
slot area (``glob_f`` / ``glob_b`` / ``glob_w``), and every relation
instance reads its own row/column coordinates as SLICES of that vector
(`MatmulInstance.{cols,rows,pad} -> instance_slices`), with the unused
high variables contributing the public padding factor
``prod_j (1 - u_j)``.  The draw is split into the seed's named vectors
(u_r/u_c, u_r2/u_c2, u_i/u_j) with sizes that degenerate to the seed's
exact tags and counts on a uniform graph, keeping the uniform transcript
bit-identical.  The slot challenges u_sf/u_sb (aux axis) and u_sw
(weight axis) range over the combined (step, node) axis, which is what
batches all layers of all T steps into each bucket's sumcheck.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.field import FQ
from repro.core.mle import expand_point
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.graph import MatmulInstance
from repro.core.pipeline.tables import kron, log2_exact
from repro.core.transcript import Transcript

Q_MOD = FQ.modulus


@dataclasses.dataclass
class ChallengeSchedule:
    u_r: List[int]; u_c: List[int]       # forward elem point (cols low)
    u_r2: List[int]; u_c2: List[int]     # backward
    u_i: List[int]; u_j: List[int]       # weight-gradient
    u_sf: List[int]; u_sb: List[int]; u_sw: List[int]   # slot axes

    @classmethod
    def draw(cls, t: Transcript, cfg: PipelineConfig) -> "ChallengeSchedule":
        lb, la, lw, lj = cfg.lb, cfg.la, cfg.lw, cfg.lj
        ls = log2_exact(cfg.s_pad)
        lsw = log2_exact(cfg.sw_pad)
        c = lambda tag, n: t.challenge_ints(tag, Q_MOD, n)
        return cls(
            u_r=c(b"u_r", lb), u_c=c(b"u_c", la - lb),
            u_r2=c(b"u_r2", lb), u_c2=c(b"u_c2", la - lb),
            u_i=c(b"u_i", lw - lj), u_j=c(b"u_j", lj),
            u_sf=c(b"u_sf", ls), u_sb=c(b"u_sb", ls), u_sw=c(b"u_sw", lsw))

    # -- global element points (little-endian: cols vary fastest) ---------
    @property
    def glob_f(self) -> List[int]:
        return list(self.u_c) + list(self.u_r)

    @property
    def glob_b(self) -> List[int]:
        return list(self.u_c2) + list(self.u_r2)

    @property
    def glob_w(self) -> List[int]:
        return list(self.u_j) + list(self.u_i)

    def glob(self, family: str) -> List[int]:
        return {"fwd": self.glob_f, "bwd": self.glob_b,
                "gw": self.glob_w}[family]


def instance_slices(inst: MatmulInstance,
                    glob: List[int]) -> Tuple[List[int], List[int], int]:
    """(u_cols, u_rows, padfac) of one instance inside its family's
    global element point: the claim tensor's column variables are the
    low slice, row variables the next, and the remaining high variables
    are bound to zero, contributing the public factor prod (1 - u_j)."""
    lc = log2_exact(inst.claim_cols)
    lr = log2_exact(inst.claim_rows)
    assert lc + lr <= len(glob), (inst, len(glob))
    u_cols = glob[:lc]
    u_rows = glob[lc:lc + lr]
    padfac = 1
    for u in glob[lc + lr:]:
        padfac = padfac * ((1 - u) % Q_MOD) % Q_MOD
    return u_cols, u_rows, padfac


def claim_point(inst: MatmulInstance, glob: List[int]) -> List[int]:
    """The claim tensor's own element point (cols low, rows high)."""
    u_cols, u_rows, _ = instance_slices(inst, glob)
    return list(u_cols) + list(u_rows)


def pad_point(point: List[int], n_vars: int) -> List[int]:
    """Zero-extend a point to the full slot element area: the extra high
    variables select the tensor's low block of the padded slot."""
    assert len(point) <= n_vars
    return list(point) + [0] * (n_vars - len(point))


def pi_bases(ch: ChallengeSchedule) -> Tuple:
    """Expanded opening bases at the three matmul points pi1/pi2/pi3."""
    e_pi1 = kron(expand_point(ch.u_sf), kron(expand_point(ch.u_r),
                                             expand_point(ch.u_c)))
    e_pi2 = kron(expand_point(ch.u_sb), kron(expand_point(ch.u_r2),
                                             expand_point(ch.u_c2)))
    e_pi3 = kron(expand_point(ch.u_sw), kron(expand_point(ch.u_i),
                                             expand_point(ch.u_j)))
    return e_pi1, e_pi2, e_pi3


@dataclasses.dataclass
class AnchorCoefs:
    """Random linear combination coefficients batching every A^{l,t} and
    G_Z^{l,t} claim of step (a) into the single anchor sumcheck (the
    generalized eq. 27, now over graph nodes AND steps).  Keys are
    (t, l) with l the claimed tensor's layer index."""
    a1: Dict[Tuple[int, int], int]   # A^l claims from the fwd sumchecks
    a2: Dict[Tuple[int, int], int]   # A^l claims from the gw sumchecks
    g1: Dict[Tuple[int, int], int]   # G_Z^l claims from the bwd sumchecks
    g2: Dict[Tuple[int, int], int]   # G_Z^l claims from the gw sumchecks

    @classmethod
    def draw(cls, t: Transcript, cfg: PipelineConfig) -> "AnchorCoefs":
        T, L = cfg.n_steps, cfg.n_layers
        c = lambda tag, ti, l: t.challenge_int(
            b"%s/%d/%d" % (tag, ti, l), Q_MOD)
        return cls(
            a1={(ti, l): c(b"aA1", ti, l)
                for ti in range(T) for l in range(1, L)},
            a2={(ti, l): c(b"aA2", ti, l)
                for ti in range(T) for l in range(1, L)},
            g1={(ti, l): c(b"aG1", ti, l)
                for ti in range(T) for l in range(2, L)},
            g2={(ti, l): c(b"aG2", ti, l)
                for ti in range(T) for l in range(1, L)})


@dataclasses.dataclass
class WeightDraws:
    """Per-(step, layer) coefficients folding all W claims (and all
    stacked points) into two combined openings of the ONE W commitment."""
    w1: Dict[Tuple[int, int], int]
    w2: Dict[Tuple[int, int], int]

    @classmethod
    def draw(cls, t: Transcript, cfg: PipelineConfig) -> "WeightDraws":
        T, L = cfg.n_steps, cfg.n_layers
        c = lambda tag, ti, l: t.challenge_int(
            b"%s/%d/%d" % (tag, ti, l), Q_MOD)
        return cls(
            w1={(ti, l): c(b"dW1", ti, l)
                for ti in range(T) for l in range(1, L + 1)},
            w2={(ti, l): c(b"dW2", ti, l)
                for ti in range(T) for l in range(1, L)})
