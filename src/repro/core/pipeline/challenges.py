"""Challenge schedule for the staged pipeline (one draw, all stages).

Every challenge is drawn from the shared Fiat-Shamir transcript in a
fixed order; the prover and the standalone verifier call the same
``draw`` classmethods at the same transcript positions.  The slot
challenges (u_sf / u_sb / u_sw) range over the combined (step, layer)
axis -- log2(l_pad) + log2(t_pad) variables -- which is what batches all
layers of all T steps into each of the three matmul sumchecks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.field import FQ
from repro.core.mle import expand_point
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.tables import kron, log2_exact
from repro.core.transcript import Transcript

Q_MOD = FQ.modulus


@dataclasses.dataclass
class ChallengeSchedule:
    u_r: List[int]; u_c: List[int]       # forward sumcheck points
    u_r2: List[int]; u_c2: List[int]     # backward
    u_i: List[int]; u_j: List[int]       # weight-gradient
    u_sf: List[int]; u_sb: List[int]; u_sw: List[int]   # slot axes

    @classmethod
    def draw(cls, t: Transcript, cfg: PipelineConfig) -> "ChallengeSchedule":
        lb = log2_exact(cfg.batch)
        ld = log2_exact(cfg.width)
        ls = log2_exact(cfg.s_pad)
        c = lambda tag, n: t.challenge_ints(tag, Q_MOD, n)
        return cls(
            u_r=c(b"u_r", lb), u_c=c(b"u_c", ld),
            u_r2=c(b"u_r2", lb), u_c2=c(b"u_c2", ld),
            u_i=c(b"u_i", ld), u_j=c(b"u_j", ld),
            u_sf=c(b"u_sf", ls), u_sb=c(b"u_sb", ls), u_sw=c(b"u_sw", ls))


def pi_bases(ch: ChallengeSchedule) -> Tuple:
    """Expanded opening bases at the three matmul points pi1/pi2/pi3."""
    e_pi1 = kron(expand_point(ch.u_sf), kron(expand_point(ch.u_r),
                                             expand_point(ch.u_c)))
    e_pi2 = kron(expand_point(ch.u_sb), kron(expand_point(ch.u_r2),
                                             expand_point(ch.u_c2)))
    e_pi3 = kron(expand_point(ch.u_sw), kron(expand_point(ch.u_i),
                                             expand_point(ch.u_j)))
    return e_pi1, e_pi2, e_pi3


@dataclasses.dataclass
class AnchorCoefs:
    """Random linear combination coefficients batching every A^{l,t} and
    G_Z^{l,t} claim of step (a) into the single anchor sumcheck (the
    generalized eq. 27, now over layers AND steps).  Keys are (t, l)."""
    a1: Dict[Tuple[int, int], int]   # A^l claims from the fwd sumcheck
    a2: Dict[Tuple[int, int], int]   # A^l claims from the gw sumcheck
    g1: Dict[Tuple[int, int], int]   # G_Z^l claims from the bwd sumcheck
    g2: Dict[Tuple[int, int], int]   # G_Z^l claims from the gw sumcheck

    @classmethod
    def draw(cls, t: Transcript, cfg: PipelineConfig) -> "AnchorCoefs":
        T, L = cfg.n_steps, cfg.n_layers
        c = lambda tag, ti, l: t.challenge_int(
            b"%s/%d/%d" % (tag, ti, l), Q_MOD)
        return cls(
            a1={(ti, l): c(b"aA1", ti, l)
                for ti in range(T) for l in range(1, L)},
            a2={(ti, l): c(b"aA2", ti, l)
                for ti in range(T) for l in range(1, L)},
            g1={(ti, l): c(b"aG1", ti, l)
                for ti in range(T) for l in range(2, L)},
            g2={(ti, l): c(b"aG2", ti, l)
                for ti in range(T) for l in range(1, L)})


@dataclasses.dataclass
class WeightDraws:
    """Per-(step, layer) coefficients folding all W claims (and all
    stacked points) into two combined openings of the ONE W commitment."""
    w1: Dict[Tuple[int, int], int]
    w2: Dict[Tuple[int, int], int]

    @classmethod
    def draw(cls, t: Transcript, cfg: PipelineConfig) -> "WeightDraws":
        T, L = cfg.n_steps, cfg.n_layers
        c = lambda tag, ti, l: t.challenge_int(
            b"%s/%d/%d" % (tag, ti, l), Q_MOD)
        return cls(
            w1={(ti, l): c(b"dW1", ti, l)
                for ti in range(T) for l in range(1, L + 1)},
            w2={(ti, l): c(b"dW2", ti, l)
                for ti in range(T) for l in range(1, L)})
