"""`ProofSession`: accumulate T training-step witnesses, emit ONE proof.

This is the FAC4DNN deployment surface: the trainer calls ``add_step``
once per batch update and ``prove`` once per aggregation window; the
committed tensors, the transcript, the bucketed matmul sumchecks, the
anchor sumcheck, the zkReLU validity argument and every IPA opening are
all shared across the window's T steps -- and, through the layer-graph
shape buckets, across heterogeneous layer shapes -- so per-step proof
size and per-step fixed proving cost fall as T grows (see
benchmarks/agg_steps.py for the measured amortization curve, including
the heterogeneous pyramid cell).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import group, ipa, pedersen, zkrelu
from repro.core.quantfc import StepWitness
from repro.core.sumcheck import SumcheckProof
from repro.core.transcript import Transcript
from repro.core.pipeline import anchor as anchor_mod
from repro.core.pipeline import matmul as matmul_mod
from repro.core.pipeline import openings as openings_mod
from repro.core.pipeline.challenges import ChallengeSchedule
from repro.core.pipeline.config import PipelineConfig, PipelineKeys
from repro.core.pipeline.profile import PhaseProfile
from repro.core.pipeline.tables import enc_tensor, rand_scalar
from repro.core.pipeline.witness import (StackedWitness, build_field_tables,
                                         stack_witnesses)


@dataclasses.dataclass
class SessionCommitments:
    """Everything the trainer publishes before the interaction; the x
    list holds the per-sample data commitments of ALL T steps, t-major
    (Section 4.4 folded-data path)."""
    x: List[int]
    y: int
    w: int
    gw: int
    zpp: int
    bq: int
    rz: int
    gap: int
    rga: int
    validity: zkrelu.ValidityCommitments

    def as_ints(self) -> List[int]:
        return (self.x + [self.y, self.w, self.gw, self.zpp, self.bq,
                          self.rz, self.gap, self.rga,
                          self.validity.com_b_ip, self.validity.com_bq1p,
                          self.validity.com_br_ip])


@dataclasses.dataclass
class AggregatedProof:
    """One transcript covering all T aggregated steps.

    Sumchecks and finals are per shape bucket (one entry per bucket, in
    the graph's bucket order); the ``*_claims`` lists carry the per-
    bucket split of the family claim target and stay empty for single-
    bucket (uniform-width) graphs, whose transcript is bit-identical to
    the seed's."""
    coms: SessionCommitments
    openings: Dict[str, int]               # claim values, by name
    sc_fwd: List[SumcheckProof]
    sc_bwd: List[SumcheckProof]
    sc_gw: List[SumcheckProof]
    sc_anchor: SumcheckProof
    fwd_finals: List[List[int]]
    bwd_finals: List[List[int]]
    gw_finals: List[List[int]]
    fwd_claims: List[int]
    bwd_claims: List[int]
    gw_claims: List[int]
    anchor_finals: List[int]
    ipas: Dict[str, ipa.IpaProof]
    validity: zkrelu.ValidityProof
    n_steps: int = 1

    def size_bytes(self) -> int:
        n = len(self.coms.as_ints()) + len(self.openings)
        for sc in (*self.sc_fwd, *self.sc_bwd, *self.sc_gw, self.sc_anchor):
            n += sum(len(m) for m in sc.messages)
        for finals in (self.fwd_finals, self.bwd_finals, self.gw_finals):
            n += sum(len(f) for f in finals)
        n += (len(self.fwd_claims) + len(self.bwd_claims)
              + len(self.gw_claims) + len(self.anchor_finals))
        total = 32 * n
        total += sum(p.size_bytes() for p in self.ipas.values())
        total += self.validity.size_bytes()
        return total


class SessionProver:
    """Two-phase prover over a stacked witness: commit, then prove."""

    def __init__(self, keys: PipelineKeys, rng: np.random.Generator,
                 profile: Optional[PhaseProfile] = None):
        self.keys = keys
        self.cfg = keys.cfg
        self.rng = rng
        self.profile = profile if profile is not None else PhaseProfile()

    # -- commitment phase --------------------------------------------------
    def commit(self, sw: StackedWitness) -> SessionCommitments:
        with self.profile.phase("commit"):
            return self._commit(sw)

    def _commit(self, sw: StackedWitness) -> SessionCommitments:
        cfg, keys, rng = self.cfg, self.keys, self.rng
        self.sw = sw
        self.tabs = build_field_tables(sw)
        self.blinds = {name: rand_scalar(rng) for name in
                       ("y", "w", "gw", "zpp", "bq", "rz", "gap", "rga")}
        self.x_blinds = [rand_scalar(rng) for _ in sw.x]

        # All multi-exponentiation commitments batch into TWO msm_many
        # dispatches: one for the T*B per-sample data rows, one for the
        # stacked tensors (each row's blind rides as an extra (h, blind)
        # MSM term, so every element matches the sequential
        # `pedersen.commit` bit-for-bit).
        com_x = group.decode_group_many(pedersen.commit_many(
            [(keys.kx, enc_tensor(x), b)
             for x, b in zip(sw.x, self.x_blinds)]))
        com_y, com_w, com_gw, com_zpp, com_rz, com_gap, com_rga = \
            group.decode_group_many(pedersen.commit_many([
                (keys.ky, self.tabs.y_t, self.blinds["y"]),
                (keys.kw, self.tabs.w_t, self.blinds["w"]),
                (keys.kw, self.tabs.gw_t, self.blinds["gw"]),
                (keys.kd, self.tabs.zpp_t, self.blinds["zpp"]),
                (keys.kd, self.tabs.rz_t, self.blinds["rz"]),
                (keys.kd, self.tabs.gap_t, self.blinds["gap"]),
                (keys.kd, self.tabs.rga_t, self.blinds["rga"])]))
        com_bq = pedersen.commit_bits(keys.k_bq, sw.bq_s.astype(np.uint32),
                                      self.blinds["bq"])

        self.aux_bits = zkrelu.build_aux_bits(
            sw.zpp_s, sw.gap_s, sw.bq_s, sw.rz_s, sw.rga_s,
            cfg.q_bits, cfg.r_bits)
        vcoms, self.vblinds = zkrelu.commit_validity(keys.validity,
                                                     self.aux_bits, rng)
        self.coms = SessionCommitments(
            x=com_x, y=com_y, w=com_w, gw=com_gw, zpp=com_zpp,
            bq=group.decode_group(com_bq), rz=com_rz,
            gap=com_gap, rga=com_rga, validity=vcoms)
        return self.coms

    # -- interactive phase (Fiat-Shamir) -----------------------------------
    def prove(self, transcript: Transcript) -> AggregatedProof:
        cfg, keys, rng = self.cfg, self.keys, self.rng
        prof = self.profile
        t = transcript
        with prof.phase("challenges"):
            t.absorb_ints(b"coms", self.coms.as_ints())
            ch = ChallengeSchedule.draw(t, cfg)

            op: Dict[str, int] = {}
            e_pi1, e_pi2, e_pi3 = openings_mod.initial_claims(
                cfg, self.tabs, ch, op, t)
        with prof.phase("matmul"):
            mat = matmul_mod.prove(cfg, self.tabs, ch, t)        # step (a)
        with prof.phase("anchor"):
            anc = anchor_mod.prove(cfg, self.tabs, ch, mat, t)   # step (b)
        with prof.phase("openings"):
            ipas, validity = openings_mod.prove(                 # step (c)
                cfg, keys, self.tabs, self.blinds, self.x_blinds,
                self.aux_bits, self.vblinds, ch, mat, anc, op,
                e_pi1, e_pi2, e_pi3, t, rng)

        return AggregatedProof(
            coms=self.coms, openings=op,
            sc_fwd=mat.fams["fwd"].scs, sc_bwd=mat.fams["bwd"].scs,
            sc_gw=mat.fams["gw"].scs, sc_anchor=anc.sc_anchor,
            fwd_finals=mat.fams["fwd"].finals,
            bwd_finals=mat.fams["bwd"].finals,
            gw_finals=mat.fams["gw"].finals,
            fwd_claims=list(mat.fams["fwd"].claims),
            bwd_claims=list(mat.fams["bwd"].claims),
            gw_claims=list(mat.fams["gw"].claims),
            anchor_finals=anc.anchor_finals,
            ipas=ipas, validity=validity, n_steps=cfg.n_steps)


class ProofSession:
    """Streaming front end: add step witnesses as training progresses,
    then emit the single aggregated proof for the window."""

    def __init__(self, keys: PipelineKeys,
                 rng: Optional[np.random.Generator] = None,
                 label: bytes = b"zkdl"):
        self.keys = keys
        self.cfg = keys.cfg
        self.rng = rng if rng is not None else np.random.default_rng()
        self.label = label
        self._steps: List[StepWitness] = []
        #: per-phase wall-clock profile of the most recent prove() call
        self.last_profile: Optional[PhaseProfile] = None

    @property
    def n_pending(self) -> int:
        return len(self._steps)

    @property
    def is_full(self) -> bool:
        return len(self._steps) >= self.cfg.n_steps

    def add_step(self, wit: StepWitness) -> int:
        """Queue one batch-update witness; returns its step index."""
        if self.is_full:
            raise ValueError(
                f"session already holds {self.cfg.n_steps} steps; "
                "prove() and start a new session")
        self._steps.append(wit)
        return len(self._steps) - 1

    def prove(self) -> AggregatedProof:
        """Stack the queued witnesses and emit the aggregated proof."""
        prof = PhaseProfile()
        t0 = time.perf_counter()
        with prof.phase("stack"):
            sw = stack_witnesses(self._steps, self.cfg)
        prover = SessionProver(self.keys, self.rng, profile=prof)
        prover.commit(sw)
        proof = prover.prove(Transcript(self.label))
        prof.total_s = time.perf_counter() - t0
        self.last_profile = prof
        return proof

    def verify(self, proof: AggregatedProof) -> bool:
        from repro.core.pipeline.verifier import verify_session
        return verify_session(self.keys, proof, label=self.label)


def prove_session(keys: PipelineKeys, wits: List[StepWitness],
                  rng: np.random.Generator,
                  label: bytes = b"zkdl") -> AggregatedProof:
    """One-shot helper: aggregate `wits` (length cfg.n_steps) -> proof."""
    session = ProofSession(keys, rng, label=label)
    for w in wits:
        session.add_step(w)
    return session.prove()
