"""`ProofSession`: accumulate T training-step witnesses, emit ONE proof.

This is the FAC4DNN deployment surface: the trainer calls ``add_step``
once per batch update and ``prove`` once per aggregation window; the
committed tensors, the transcript, the bucketed matmul sumchecks, the
anchor sumcheck, the zkReLU validity argument and every IPA opening are
all shared across the window's T steps -- and, through the layer-graph
shape buckets, across heterogeneous layer shapes -- so per-step proof
size and per-step fixed proving cost fall as T grows (see
benchmarks/agg_steps.py for the measured amortization curve, including
the heterogeneous pyramid cell).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import group, ipa, pedersen, zkrelu
from repro.core.quantfc import StepWitness
from repro.core.sumcheck import SumcheckProof
from repro.core.transcript import Transcript
from repro.core.pipeline import anchor as anchor_mod
from repro.core.pipeline import matmul as matmul_mod
from repro.core.pipeline import openings as openings_mod
from repro.core.pipeline.challenges import ChallengeSchedule
from repro.core.pipeline.config import PipelineConfig, PipelineKeys
from repro.core.pipeline.profile import PhaseProfile
from repro.core.pipeline.tables import enc_tensor, rand_scalar
from repro.core.pipeline.witness import (StackedWitness, build_field_tables,
                                         stack_witnesses)


@dataclasses.dataclass
class SessionCommitments:
    """Everything the trainer publishes before the interaction, keyed by
    the graph's commitment schema (`LayerGraph.commit_slots`): ``slots``
    maps each declared tensor-slot name ("y", "w", "zpp", ...) to its
    stacked Pedersen commitment, in schema order; the x list holds the
    per-sample data commitments of ALL T steps, t-major (Section 4.4
    folded-data path).  Slot commitments are also readable as attributes
    (``coms.zpp``)."""
    x: List[int]
    slots: Dict[str, int]
    validity: zkrelu.ValidityCommitments

    def __getattr__(self, name: str):
        if name.startswith("_") or name == "slots":
            raise AttributeError(name)
        try:
            return self.slots[name]
        except KeyError:
            raise AttributeError(name) from None

    def as_ints(self) -> List[int]:
        """Transcript absorption order: x rows, then the schema slots in
        declaration order, then the validity commitments."""
        return (self.x + list(self.slots.values())
                + [self.validity.com_b_ip, self.validity.com_bq1,
                   self.validity.com_bq1p, self.validity.com_br_ip])


@dataclasses.dataclass
class AggregatedProof:
    """One transcript covering all T aggregated steps.

    Sumchecks and finals are per shape bucket (one entry per bucket, in
    the graph's bucket order); the ``*_claims`` lists carry the per-
    bucket split of the family claim target and stay empty for single-
    bucket (uniform-width) graphs, whose transcript is bit-identical to
    the seed's."""
    coms: SessionCommitments
    openings: Dict[str, int]               # claim values, by name
    sc_fwd: List[SumcheckProof]
    sc_bwd: List[SumcheckProof]
    sc_gw: List[SumcheckProof]
    sc_anchor: SumcheckProof
    fwd_finals: List[List[int]]
    bwd_finals: List[List[int]]
    gw_finals: List[List[int]]
    fwd_claims: List[int]
    bwd_claims: List[int]
    gw_claims: List[int]
    anchor_finals: List[int]
    #: the ONE merged pair-IPA covering every committed-tensor claim,
    #: both data folds AND both zkReLU validity statements (openings.py)
    ipa_agg: ipa.IpaProof
    n_steps: int = 1

    def size_bytes(self) -> int:
        """Exact wire size: the length of the canonical byte encoding
        (`proofio.encode_proof`), not an in-memory estimate."""
        from repro.core.pipeline.proofio import encode_proof
        return len(encode_proof(self))


def _as_pipeline_keys(keys) -> PipelineKeys:
    """Accept either a raw `PipelineKeys` or a `ProvingKey` wrapper (the
    `compile()` artifact) everywhere the prover takes key material."""
    if isinstance(keys, PipelineKeys):
        return keys
    inner = getattr(keys, "keys", None)
    if isinstance(inner, PipelineKeys):
        return inner
    raise TypeError(f"expected PipelineKeys or ProvingKey, got {keys!r}")


class SessionProver:
    """Two-phase prover over a stacked witness: commit, then prove."""

    def __init__(self, keys, rng: np.random.Generator,
                 profile: Optional[PhaseProfile] = None):
        self.keys = _as_pipeline_keys(keys)
        self.cfg = self.keys.cfg
        self.rng = rng
        self.profile = profile if profile is not None else PhaseProfile()

    # -- commitment phase --------------------------------------------------
    def commit(self, sw: StackedWitness) -> SessionCommitments:
        with self.profile.phase("commit"):
            return self._commit(sw)

    def _commit(self, sw: StackedWitness) -> SessionCommitments:
        cfg, keys, rng = self.cfg, self.keys, self.rng
        schema = cfg.graph.commit_slots
        self.sw = sw
        self.tabs = build_field_tables(sw)
        self.blinds = {spec.name: rand_scalar(rng) for spec in schema}
        self.x_blinds = [rand_scalar(rng) for _ in sw.x]

        # All multi-exponentiation commitments batch into TWO msm_many
        # dispatches: one for the T*B per-sample data rows, one for the
        # stacked slot tensors in schema order (each row's blind rides
        # as an extra (h, blind) MSM term, so every element matches the
        # sequential `pedersen.commit` bit-for-bit).  Bit-matrix slots
        # (B_{Q-1}) commit under the zkReLU G-column basis instead.
        com_x = group.decode_group_many(pedersen.commit_many(
            [(keys.kx, enc_tensor(x), b)
             for x, b in zip(sw.x, self.x_blinds)]))
        msm_specs = [s for s in schema if not s.bits]
        msm_coms = group.decode_group_many(pedersen.commit_many(
            [(keys.slot_key(s), self.tabs.tabs[s.name],
              self.blinds[s.name]) for s in msm_specs]))
        slot_coms = {s.name: c for s, c in zip(msm_specs, msm_coms)}
        for s in schema:
            if s.bits:
                slot_coms[s.name] = group.decode_group(pedersen.commit_bits(
                    keys.k_bq, sw.tensors[s.name].astype(np.uint32),
                    self.blinds[s.name]))
        slot_coms = {s.name: slot_coms[s.name] for s in schema}

        self.aux_bits = zkrelu.build_aux_bits(
            sw.zpp_s, sw.gap_s, sw.bq_s, sw.rz_s, sw.rga_s,
            cfg.q_bits, cfg.r_bits)
        vcoms, self.vblinds = zkrelu.commit_validity(keys.validity,
                                                     self.aux_bits, rng)
        self.coms = SessionCommitments(x=com_x, slots=slot_coms,
                                       validity=vcoms)
        return self.coms

    # -- interactive phase (Fiat-Shamir) -----------------------------------
    def prove(self, transcript: Transcript) -> AggregatedProof:
        cfg, keys, rng = self.cfg, self.keys, self.rng
        prof = self.profile
        t = transcript
        with prof.phase("challenges"):
            t.absorb_ints(b"coms", self.coms.as_ints())
            ch = ChallengeSchedule.draw(t, cfg)

            op: Dict[str, int] = {}
            e_pi1, e_pi2, e_pi3 = openings_mod.initial_claims(
                cfg, self.tabs, ch, op, t)
        with prof.phase("matmul"):
            mat = matmul_mod.prove(cfg, self.tabs, ch, t)        # step (a)
        with prof.phase("anchor"):
            anc = anchor_mod.prove(cfg, self.tabs, ch, mat, t)   # step (b)
        with prof.phase("openings"):
            ipa_agg = openings_mod.prove(                        # step (c)
                cfg, keys, self.tabs, self.blinds, self.x_blinds,
                self.aux_bits, self.vblinds, ch, mat, anc, op,
                e_pi1, e_pi2, e_pi3, t, rng, prof=prof)

        return AggregatedProof(
            coms=self.coms, openings=op,
            sc_fwd=mat.fams["fwd"].scs, sc_bwd=mat.fams["bwd"].scs,
            sc_gw=mat.fams["gw"].scs, sc_anchor=anc.sc_anchor,
            fwd_finals=mat.fams["fwd"].finals,
            bwd_finals=mat.fams["bwd"].finals,
            gw_finals=mat.fams["gw"].finals,
            fwd_claims=list(mat.fams["fwd"].claims),
            bwd_claims=list(mat.fams["bwd"].claims),
            gw_claims=list(mat.fams["gw"].claims),
            anchor_finals=anc.anchor_finals,
            ipa_agg=ipa_agg, n_steps=cfg.n_steps)


class ProofSession:
    """Streaming front end: add step witnesses as training progresses,
    then emit the single aggregated proof for the window."""

    def __init__(self, keys,
                 rng: Optional[np.random.Generator] = None,
                 label: bytes = b"zkdl"):
        self.keys = _as_pipeline_keys(keys)
        self.cfg = self.keys.cfg
        self.rng = rng if rng is not None else np.random.default_rng()
        self.label = label
        self._steps: List[StepWitness] = []
        #: per-phase wall-clock profile of the most recent prove() call
        self.last_profile: Optional[PhaseProfile] = None

    @property
    def n_pending(self) -> int:
        return len(self._steps)

    @property
    def is_full(self) -> bool:
        return len(self._steps) >= self.cfg.n_steps

    def add_step(self, wit: StepWitness) -> int:
        """Queue one batch-update witness; returns its step index."""
        if self.is_full:
            raise ValueError(
                f"session already holds {self.cfg.n_steps} steps; "
                "prove() and start a new session")
        self._steps.append(wit)
        return len(self._steps) - 1

    def prove(self) -> AggregatedProof:
        """Stack the queued witnesses and emit the aggregated proof."""
        prof = PhaseProfile()
        t0 = time.perf_counter()
        with prof.phase("stack"):
            sw = stack_witnesses(self._steps, self.cfg)
        prover = SessionProver(self.keys, self.rng, profile=prof)
        prover.commit(sw)
        proof = prover.prove(Transcript(self.label))
        prof.total_s = time.perf_counter() - t0
        self.last_profile = prof
        return proof

    def verify(self, proof: AggregatedProof) -> bool:
        from repro.core.pipeline.verifier import verify_session
        return verify_session(self.keys, proof, label=self.label)


def prove_session(keys: PipelineKeys, wits: List[StepWitness],
                  rng: np.random.Generator,
                  label: bytes = b"zkdl") -> AggregatedProof:
    """One-shot helper: aggregate `wits` (length cfg.n_steps) -> proof."""
    session = ProofSession(keys, rng, label=label)
    for w in wits:
        session.add_step(w)
    return session.prove()
