"""REMOVED: the `repro.core.zkdl` compat shim is retired.

The Protocol-2 monolith became the staged `repro.core.pipeline` package
(PR 1), the shim's single-step wrappers became the T=1 degenerate case
of `ProofSession` (PR 2), and the public surface is now the graph-first
compile -> prove -> verify lifecycle.  This one-release stub raises with
a migration hint on any attribute access; it will be deleted next
release.

Migration map:

    zkdl.ZkdlConfig(...)        -> pipeline.PipelineConfig(..., n_steps=1)
                                   or pipeline.compile(graph, quant)
    zkdl.make_keys(cfg)         -> pipeline.make_keys(cfg) / compile()
    zkdl.Prover(keys, rng)      -> pipeline.ProofSession(keys, rng)
                                   (.add_step(wit); .prove())
    zkdl.prove_step(keys, w, r) -> pipeline.prove_session(keys, [w], r)
    zkdl.verify_step(keys, p)   -> pipeline.verify_session(keys, p)
    zkdl.verify(keys, p, t)     -> pipeline.verify(keys, p, t)
                                   (serialized: pipeline.verify_bytes)
"""
from __future__ import annotations

_HINT = (
    "repro.core.zkdl was removed: use repro.core.pipeline instead "
    "(compile(graph, quant) -> (ProvingKey, VerifyingKey); "
    "ProofSession(pk).add_step(wit) / .prove(); verify_bytes(vk, "
    "encode_proof(proof)) — n_steps=1 reproduces the old single-step "
    "protocol exactly).  See the migration map in repro/core/zkdl.py "
    "and repro/core/pipeline/README.md."
)


def __getattr__(name: str):
    if name.startswith("__"):
        raise AttributeError(name)
    raise ImportError(f"repro.core.zkdl.{name} is gone — {_HINT}")
