"""zkDL Protocol 2: full zero-knowledge proof of one FCNN batch update.

Proof structure (mirrors Fig. 3 -- each step batches ALL layers with one
set of randomness, which is what collapses proving time by O(L)):

  step (a) three batched matmul sumchecks (Thaler's specialized GKR) over
           eqs (30)/(33)/(34), all layers random-linearly combined;
  step (b) the "anchor" sumcheck -- the generalized eq. (27) -- reducing
           every claim on the uncommitted tensors A^l / G_Z^l to claims on
           the committed auxiliary tensors at one point u_star;
  step (c) zkReLU validity of the auxiliary inputs (Section 4.1) plus
           Pedersen/IPA openings of every committed tensor.

Claims on Z^l and G_A^l never need their own commitments: eqs (3)/(5) are
linear, so the verifier assembles them homomorphically from aux openings
(exactly the paper's use of commitment homomorphism).  G_Z^L similarly
reduces to Z''^L, B^L and Y via eq. (32).

Per-tensor opening claims at multiple points are folded into a single IPA
by combining the public vectors (<T, b1> + rho <T, b2> = <T, b1 + rho b2>),
so the proof stays logarithmic in D*Q*L.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.field import FQ, add, sub, mont_mul, encode_i64, decode
from repro.core import group, ipa, pedersen, zkrelu
from repro.core.mle import (enc, enc_vec, expand_point, hexpand_point,
                            heval_point_product, fdot, hadd, hmul, hsub)
from repro.core.sumcheck import (sumcheck_prove, sumcheck_verify,
                                 combine_final, SumcheckProof)
from repro.core.transcript import Transcript
from repro.core.quantfc import StepWitness

Q_MOD = FQ.modulus


def _next_pow2(n: int) -> int:
    m = 1
    while m < n:
        m *= 2
    return m


def _log2(n: int) -> int:
    assert n & (n - 1) == 0
    return n.bit_length() - 1


def _rand(rng) -> int:
    return int(rng.integers(0, Q_MOD, dtype=np.uint64)) % Q_MOD


def _enc_tensor(x: np.ndarray) -> jnp.ndarray:
    """int64 array -> flat (n,4) Montgomery table."""
    return jnp.asarray(encode_i64(FQ, x.reshape(-1))).reshape(-1, 4)


def _dec(x) -> int:
    return int(decode(FQ, x)[()])


def _fix_rows(table: jnp.ndarray, point: List[int]) -> jnp.ndarray:
    """table (R, C, 4); fold ROW vars (little-endian) -> (C, 4)."""
    for r in point:
        rl = enc(r)
        even, odd = table[0::2], table[1::2]
        table = add(FQ, even, mont_mul(FQ, sub(FQ, odd, even), rl[None, None]))
    return table[0]


def _fix_cols(table: jnp.ndarray, point: List[int]) -> jnp.ndarray:
    """table (R, C, 4); fold COL vars -> (R, 4)."""
    for r in point:
        rl = enc(r)
        even, odd = table[:, 0::2], table[:, 1::2]
        table = add(FQ, even, mont_mul(FQ, sub(FQ, odd, even), rl[None, None]))
    return table[:, 0]


def _kron(hi: jnp.ndarray, lo: jnp.ndarray) -> jnp.ndarray:
    """(a,4) x (b,4) -> (a*b,4) with lo varying fastest (low MLE vars)."""
    return mont_mul(FQ, hi[:, None, :], lo[None, :, :]).reshape(-1, 4)


def _weight_table(weights: Dict[int, int], n: int) -> jnp.ndarray:
    vec = np.zeros(n, dtype=object)
    for i, w in weights.items():
        vec[i] = w % Q_MOD
    return enc_vec(list(vec))


@dataclasses.dataclass(frozen=True)
class ZkdlConfig:
    n_layers: int
    batch: int            # power of 2
    width: int            # power of 2 (layer in/out dim, padded)
    q_bits: int
    r_bits: int

    @property
    def l_pad(self) -> int:
        return _next_pow2(self.n_layers)

    @property
    def d_elem(self) -> int:
        return self.batch * self.width

    @property
    def d_stack(self) -> int:
        return self.l_pad * self.d_elem


@dataclasses.dataclass(frozen=True)
class ZkdlKeys:
    cfg: ZkdlConfig
    kd: pedersen.CommitKey        # stacked aux tensors (d_stack)
    kw: pedersen.CommitKey        # stacked W / G_W (l_pad * width^2)
    kx: pedersen.CommitKey        # per-sample data vectors (width)
    ky: pedersen.CommitKey        # labels (d_elem)
    k_bq: pedersen.CommitKey      # B_{Q-1} under the G-column basis
    validity: zkrelu.ValidityKeys


def make_keys(cfg: ZkdlConfig) -> ZkdlKeys:
    vk = zkrelu.make_validity_keys(cfg.d_stack, cfg.q_bits, cfg.r_bits)
    return ZkdlKeys(
        cfg=cfg,
        kd=pedersen.make_key(b"zkdl/aux", cfg.d_stack),
        kw=pedersen.make_key(b"zkdl/w", cfg.l_pad * cfg.width * cfg.width),
        kx=pedersen.make_key(b"zkdl/x", cfg.width),
        ky=pedersen.make_key(b"zkdl/y", cfg.d_elem),
        k_bq=pedersen.CommitKey(vk.g_col, vk.h_blind, b"zkdl/bq"),
        validity=vk)


@dataclasses.dataclass
class ZkdlCommitments:
    """Everything the trainer publishes before the interaction."""
    x: List[int]                  # per-sample commitments (Section 4.4)
    y: int
    w: int
    gw: int
    zpp: int
    bq: int
    rz: int
    gap: int
    rga: int
    validity: zkrelu.ValidityCommitments

    def as_ints(self) -> List[int]:
        return (self.x + [self.y, self.w, self.gw, self.zpp, self.bq,
                          self.rz, self.gap, self.rga,
                          self.validity.com_b_ip, self.validity.com_bq1p,
                          self.validity.com_br_ip])


@dataclasses.dataclass
class ZkdlProof:
    coms: ZkdlCommitments
    openings: Dict[str, int]               # claim values, by name
    sc_fwd: SumcheckProof
    sc_bwd: SumcheckProof
    sc_gw: SumcheckProof
    sc_anchor: SumcheckProof
    fwd_finals: List[int]
    bwd_finals: List[int]
    gw_finals: List[int]
    anchor_finals: List[int]
    ipas: Dict[str, ipa.IpaProof]
    validity: zkrelu.ValidityProof

    def size_bytes(self) -> int:
        n = len(self.coms.as_ints()) + len(self.openings)
        for sc in (self.sc_fwd, self.sc_bwd, self.sc_gw, self.sc_anchor):
            n += sum(len(m) for m in sc.messages)
        n += (len(self.fwd_finals) + len(self.bwd_finals)
              + len(self.gw_finals) + len(self.anchor_finals))
        total = 32 * n
        total += sum(p.size_bytes() for p in self.ipas.values())
        total += self.validity.size_bytes()
        return total


def _stack_aux(per_layer: List[np.ndarray], cfg: ZkdlConfig) -> np.ndarray:
    """list of (B, d) int64 -> (l_pad * d_elem,) int64 with zero padding."""
    out = np.zeros((cfg.l_pad, cfg.d_elem), dtype=np.int64)
    for i, t in enumerate(per_layer):
        out[i] = t.reshape(-1)
    return out.reshape(-1)


class Prover:
    def __init__(self, keys: ZkdlKeys, rng: np.random.Generator):
        self.keys = keys
        self.cfg = keys.cfg
        self.rng = rng

    # -- commitment phase --------------------------------------------------
    def commit(self, wit: StepWitness):
        cfg, keys, rng = self.cfg, self.keys, self.rng
        L = cfg.n_layers
        self.wit = wit
        self.zpp_s = _stack_aux(wit.zpp, cfg)
        self.bq_s = _stack_aux(wit.b, cfg)
        self.rz_s = _stack_aux(wit.rz, cfg)
        self.gap_s = _stack_aux(wit.gap, cfg)
        self.rga_s = _stack_aux(wit.rga, cfg)
        w_stack = np.zeros((cfg.l_pad, cfg.width * cfg.width), dtype=np.int64)
        gw_stack = np.zeros_like(w_stack)
        for i in range(L):
            w_stack[i] = wit.w[i].reshape(-1)
            gw_stack[i] = wit.gw[i].reshape(-1)
        self.w_s = w_stack.reshape(-1)
        self.gw_s = gw_stack.reshape(-1)

        self.blinds = {name: _rand(rng) for name in
                       ("y", "w", "gw", "zpp", "bq", "rz", "gap", "rga")}
        self.x_blinds = [_rand(rng) for _ in range(cfg.batch)]

        # NOTE: narrow MSM windows (nbits < 61) are only sound for
        # UNSIGNED tensors -- negative values map to ~61-bit field elements.
        qb = cfg.q_bits
        com_x = [group.decode_group(pedersen.commit(
            keys.kx, _enc_tensor(wit.x[i]), self.x_blinds[i]))
            for i in range(cfg.batch)]
        com_y = pedersen.commit(keys.ky, _enc_tensor(wit.y), self.blinds["y"])
        com_w = pedersen.commit(keys.kw, _enc_tensor(self.w_s),
                                self.blinds["w"])
        com_gw = pedersen.commit(keys.kw, _enc_tensor(self.gw_s),
                                 self.blinds["gw"])
        com_zpp = pedersen.commit(keys.kd, _enc_tensor(self.zpp_s),
                                  self.blinds["zpp"], nbits=qb)
        com_bq = pedersen.commit_bits(keys.k_bq, self.bq_s.astype(np.uint32),
                                      self.blinds["bq"])
        com_rz = pedersen.commit(keys.kd, _enc_tensor(self.rz_s),
                                 self.blinds["rz"], nbits=cfg.r_bits + 1)
        com_gap = pedersen.commit(keys.kd, _enc_tensor(self.gap_s),
                                  self.blinds["gap"])
        com_rga = pedersen.commit(keys.kd, _enc_tensor(self.rga_s),
                                  self.blinds["rga"], nbits=cfg.r_bits + 1)

        self.aux_bits = zkrelu.build_aux_bits(
            self.zpp_s, self.gap_s, self.bq_s, self.rz_s, self.rga_s,
            cfg.q_bits, cfg.r_bits)
        vcoms, self.vblinds = zkrelu.commit_validity(keys.validity,
                                                     self.aux_bits, rng)
        self.coms = ZkdlCommitments(
            x=com_x, y=group.decode_group(com_y), w=group.decode_group(com_w),
            gw=group.decode_group(com_gw), zpp=group.decode_group(com_zpp),
            bq=group.decode_group(com_bq), rz=group.decode_group(com_rz),
            gap=group.decode_group(com_gap), rga=group.decode_group(com_rga),
            validity=vcoms)
        return self.coms

    # -- interactive phase (Fiat-Shamir) ------------------------------------
    def prove(self, transcript: Transcript) -> ZkdlProof:
        cfg, keys, rng, wit = self.cfg, self.keys, self.rng, self.wit
        L, B, d = cfg.n_layers, cfg.batch, cfg.width
        lb, ld, ll = _log2(B), _log2(d), _log2(cfg.l_pad)
        t = transcript
        t.absorb_ints(b"coms", self.coms.as_ints())

        ch = _Challenges.draw(t, lb, ld, ll)
        # field tables
        a_tabs = [_enc_tensor(a).reshape(B, d, 4) for a in wit.a]
        gz_tabs = [_enc_tensor(g).reshape(B, d, 4) for g in wit.gz]
        w_tabs = [_enc_tensor(w).reshape(d, d, 4) for w in wit.w]
        zpp_t = _enc_tensor(self.zpp_s)
        bq_t = _enc_tensor(self.bq_s)
        rz_t = _enc_tensor(self.rz_s)
        gap_t = _enc_tensor(self.gap_s)
        rga_t = _enc_tensor(self.rga_s)
        w_t = _enc_tensor(self.w_s)
        gw_t = _enc_tensor(self.gw_s)
        y_t = _enc_tensor(wit.y)
        x_tabs = [_enc_tensor(wit.x[i]) for i in range(B)]

        # opening claims a1..a8 at pi1/pi2/pi3
        e_pi1 = _kron(expand_point(ch.u_sf), _kron(expand_point(ch.u_r),
                                                   expand_point(ch.u_c)))
        e_pi2 = _kron(expand_point(ch.u_sb), _kron(expand_point(ch.u_r2),
                                                   expand_point(ch.u_c2)))
        e_pi3 = _kron(expand_point(ch.u_sw), _kron(expand_point(ch.u_i),
                                                   expand_point(ch.u_j)))
        op: Dict[str, int] = {}
        op["a1"] = _dec(fdot(zpp_t, e_pi1))
        op["a2"] = _dec(fdot(bq_t, e_pi1))
        op["a3"] = _dec(fdot(rz_t, e_pi1))
        op["a4"] = _dec(fdot(gap_t, e_pi2))
        op["a5"] = _dec(fdot(rga_t, e_pi2))
        op["a6"] = _dec(fdot(gw_t, e_pi3))
        t.absorb_ints(b"op1", [op[k] for k in ("a1", "a2", "a3", "a4", "a5", "a6")])

        # ---------- step (a): three batched matmul sumchecks ----------------
        ef = hexpand_point(ch.u_sf)
        eb = hexpand_point(ch.u_sb)
        ew = hexpand_point(ch.u_sw)
        # forward: sum_l ef[l-1] Z~^l(u_r,u_c) = sum_w A W
        fwd_tables, fwd_products, fwd_coefs = [], [], []
        for l in range(1, L + 1):
            fa = _fix_rows(a_tabs[l - 1], ch.u_r)
            fw = _fix_cols(w_tabs[l - 1], ch.u_c)
            fwd_tables += [fa, fw]
            fwd_products.append((2 * (l - 1), 2 * (l - 1) + 1))
            fwd_coefs.append(ef[l - 1])
        sc_fwd, w1, fwd_finals = sumcheck_prove(fwd_tables, fwd_products, t,
                                                b"fwd", coefs=fwd_coefs)
        # backward: sum_l eb[l-1] GA~^l(u_r2,u_c2) = sum_w GZ^{l+1} W^{l+1}
        bwd_tables, bwd_products, bwd_coefs = [], [], []
        for l in range(1, L):
            fg = _fix_rows(gz_tabs[l], ch.u_r2)       # GZ^{l+1}
            fw = _fix_rows(w_tabs[l], ch.u_c2)        # W^{l+1} rows fixed
            bwd_tables += [fg, fw]
            bwd_products.append((2 * (l - 1), 2 * (l - 1) + 1))
            bwd_coefs.append(eb[l - 1])
        sc_bwd, w2, bwd_finals = sumcheck_prove(bwd_tables, bwd_products, t,
                                                b"bwd", coefs=bwd_coefs)
        # gw: sum_l ew[l-1] GW~^l(u_i,u_j) = sum_b GZ^l A^{l-1}
        gw_tables, gw_products, gw_coefs = [], [], []
        for l in range(1, L + 1):
            fg = _fix_cols(gz_tabs[l - 1], ch.u_i)
            fa = _fix_cols(a_tabs[l - 1], ch.u_j)
            gw_tables += [fg, fa]
            gw_products.append((2 * (l - 1), 2 * (l - 1) + 1))
            gw_coefs.append(ew[l - 1])
        sc_gw, w3, gw_finals = sumcheck_prove(gw_tables, gw_products, t,
                                              b"gw", coefs=gw_coefs)

        # ---------- step (b): anchor sumcheck (generalized eq. 27) ----------
        pt_f = w1 + ch.u_r          # A claims from fwd
        pt_g = ch.u_j + w3          # A claims from gw
        pt_b = w2 + ch.u_r2         # GZ claims from bwd
        pt_w = ch.u_i + w3          # GZ claims from gw
        al = _AnchorCoefs.draw(t, L)
        wA1 = _weight_table({l - 1: al.a1[l] for l in range(1, L)}, cfg.l_pad)
        wA2 = _weight_table({l - 1: al.a2[l] for l in range(1, L)}, cfg.l_pad)
        wG1 = _weight_table({l - 1: al.g1[l] for l in range(2, L)}, cfg.l_pad)
        wG2 = _weight_table({l - 1: al.g2[l] for l in range(1, L)}, cfg.l_pad)
        pa = add(FQ, _kron(wA1, expand_point(pt_f)),
                 _kron(wA2, expand_point(pt_g)))
        pg = add(FQ, _kron(wG1, expand_point(pt_b)),
                 _kron(wG2, expand_point(pt_w)))
        one_tab = jnp.broadcast_to(enc(1), (cfg.d_stack, 4)).astype(jnp.uint32)
        one_b = sub(FQ, one_tab, bq_t)
        anchor_tables = [one_b, zpp_t, gap_t, pa, pg]
        anchor_products = [(0, 3, 1), (0, 4, 2)]
        sc_anchor, u_star, anchor_finals = sumcheck_prove(
            anchor_tables, anchor_products, t, b"anchor")

        # remainder openings at u_star (for v_r) and derived claims
        e_star = expand_point(u_star)
        op["a7"] = _dec(fdot(rz_t, e_star))
        op["a8"] = _dec(fdot(rga_t, e_star))
        t.absorb_ints(b"op2", [op["a7"], op["a8"]])
        upp = t.challenge_int(b"upp", Q_MOD)
        u_relu = u_star + [upp]
        f_oneb, f_zpp, f_gap = anchor_finals[0], anchor_finals[1], anchor_finals[2]
        v = ((1 - upp) * f_zpp + upp * f_gap) % Q_MOD
        v_q1 = (1 - f_oneb) % Q_MOD
        v_r = ((1 - upp) * op["a7"] + upp * op["a8"]) % Q_MOD
        t.absorb_ints(b"vclaims", [v, v_q1, v_r])

        # GZ^L linear reduction points (eq. 32)
        eL = _weight_table({L - 1: 1}, cfg.l_pad)
        b_gzl_b = _kron(eL, expand_point(pt_b))
        b_gzl_w = _kron(eL, expand_point(pt_w))
        op["zL_b"] = _dec(fdot(zpp_t, b_gzl_b))
        op["bL_b"] = _dec(fdot(bq_t, b_gzl_b))
        op["y_b"] = _dec(fdot(y_t, expand_point(pt_b)))
        op["zL_w"] = _dec(fdot(zpp_t, b_gzl_w))
        op["bL_w"] = _dec(fdot(bq_t, b_gzl_w))
        op["y_w"] = _dec(fdot(y_t, expand_point(pt_w)))
        # W / GW / X claims come straight from sumcheck finals (bound there)
        t.absorb_ints(b"op3", [op[k] for k in ("zL_b", "bL_b", "y_b",
                                               "zL_w", "bL_w", "y_w")])

        # ---------- step (c): openings + zkReLU validity ---------------------
        ipas: Dict[str, ipa.IpaProof] = {}

        def multi_open(name, table, key, blind, claims_pts):
            """Batch several (b_pub, claim) for ONE tensor into one IPA."""
            rho = t.challenge_int(b"rho/" + name.encode(), Q_MOD)
            combined_b = None
            combined_claim = 0
            rpow = 1
            for b_pub, claim in claims_pts:
                scaled = mont_mul(FQ, b_pub, enc(rpow)[None])
                combined_b = scaled if combined_b is None else add(FQ, combined_b, scaled)
                combined_claim = (combined_claim + rpow * claim) % Q_MOD
                rpow = rpow * rho % Q_MOD
            ipas[name] = ipa.open_prove(key, table, combined_b, blind,
                                        combined_claim, t, rng)

        multi_open("zpp", zpp_t, keys.kd, self.blinds["zpp"],
                   [(e_pi1, op["a1"]), (e_star, f_zpp),
                    (b_gzl_b, op["zL_b"]), (b_gzl_w, op["zL_w"])])
        multi_open("bq", bq_t, keys.k_bq, self.blinds["bq"],
                   [(e_pi1, op["a2"]), (e_star, v_q1),
                    (b_gzl_b, op["bL_b"]), (b_gzl_w, op["bL_w"])])
        multi_open("rz", rz_t, keys.kd, self.blinds["rz"],
                   [(e_pi1, op["a3"]), (e_star, op["a7"])])
        multi_open("gap", gap_t, keys.kd, self.blinds["gap"],
                   [(e_pi2, op["a4"]), (e_star, f_gap)])
        multi_open("rga", rga_t, keys.kd, self.blinds["rga"],
                   [(e_pi2, op["a5"]), (e_star, op["a8"])])
        # W: two stacked points, fresh per-layer weights
        dlt = _WeightDraws.draw(t, L)
        wW1 = _weight_table({l - 1: dlt.w1[l] for l in range(1, L + 1)}, cfg.l_pad)
        wW2 = _weight_table({l: dlt.w2[l] for l in range(1, L)}, cfg.l_pad)
        b_w1 = _kron(wW1, _kron(expand_point(w1), expand_point(ch.u_c)))
        b_w2 = _kron(wW2, _kron(expand_point(ch.u_c2), expand_point(w2)))
        cl_w1 = 0
        for l in range(1, L + 1):
            cl_w1 = (cl_w1 + dlt.w1[l] * fwd_finals[2 * (l - 1) + 1]) % Q_MOD
        cl_w2 = 0
        for l in range(1, L):
            cl_w2 = (cl_w2 + dlt.w2[l] * bwd_finals[2 * (l - 1) + 1]) % Q_MOD
        multi_open("w", w_t, keys.kw, self.blinds["w"],
                   [(b_w1, cl_w1), (b_w2, cl_w2)])
        multi_open("gw", gw_t, keys.kw, self.blinds["gw"], [(e_pi3, op["a6"])])
        # Y at pt_b and pt_w
        multi_open("y", y_t, keys.ky, self.blinds["y"],
                   [(expand_point(pt_b), op["y_b"]),
                    (expand_point(pt_w), op["y_w"])])
        # X openings (Section 4.4 folded-data path): two folds
        for tag, row_pt, col_pt, claim in (
                ("x1", ch.u_r, w1, fwd_finals[0]),
                ("x2", w3, ch.u_j, gw_finals[1])):
            e_row = hexpand_point(row_pt)
            folded = None
            for i in range(B):
                s = mont_mul(FQ, x_tabs[i], enc(e_row[i])[None])
                folded = s if folded is None else add(FQ, folded, s)
            blind_f = sum(e_row[i] * self.x_blinds[i] for i in range(B)) % Q_MOD
            ipas[tag] = ipa.open_prove(keys.kx, folded, expand_point(col_pt),
                                       blind_f, claim, t, rng)

        validity = zkrelu.prove_validity(
            keys.validity, self.aux_bits, self.vblinds, u_relu,
            v, v_q1, v_r, self.blinds["bq"], t, rng)

        return ZkdlProof(
            coms=self.coms, openings=op, sc_fwd=sc_fwd, sc_bwd=sc_bwd,
            sc_gw=sc_gw, sc_anchor=sc_anchor, fwd_finals=fwd_finals,
            bwd_finals=bwd_finals, gw_finals=gw_finals,
            anchor_finals=anchor_finals, ipas=ipas, validity=validity)


@dataclasses.dataclass
class _Challenges:
    u_r: List[int]; u_c: List[int]
    u_r2: List[int]; u_c2: List[int]
    u_i: List[int]; u_j: List[int]
    u_sf: List[int]; u_sb: List[int]; u_sw: List[int]

    @staticmethod
    def draw(t: Transcript, lb: int, ld: int, ll: int) -> "_Challenges":
        c = lambda tag, n: t.challenge_ints(tag, Q_MOD, n)
        return _Challenges(
            u_r=c(b"u_r", lb), u_c=c(b"u_c", ld),
            u_r2=c(b"u_r2", lb), u_c2=c(b"u_c2", ld),
            u_i=c(b"u_i", ld), u_j=c(b"u_j", ld),
            u_sf=c(b"u_sf", ll), u_sb=c(b"u_sb", ll), u_sw=c(b"u_sw", ll))


@dataclasses.dataclass
class _AnchorCoefs:
    a1: Dict[int, int]; a2: Dict[int, int]
    g1: Dict[int, int]; g2: Dict[int, int]

    @staticmethod
    def draw(t: Transcript, L: int) -> "_AnchorCoefs":
        return _AnchorCoefs(
            a1={l: t.challenge_int(b"aA1/%d" % l, Q_MOD) for l in range(1, L)},
            a2={l: t.challenge_int(b"aA2/%d" % l, Q_MOD) for l in range(1, L)},
            g1={l: t.challenge_int(b"aG1/%d" % l, Q_MOD) for l in range(2, L)},
            g2={l: t.challenge_int(b"aG2/%d" % l, Q_MOD) for l in range(1, L)})


@dataclasses.dataclass
class _WeightDraws:
    w1: Dict[int, int]
    w2: Dict[int, int]

    @staticmethod
    def draw(t: Transcript, L: int) -> "_WeightDraws":
        return _WeightDraws(
            w1={l: t.challenge_int(b"dW1/%d" % l, Q_MOD) for l in range(1, L + 1)},
            w2={l: t.challenge_int(b"dW2/%d" % l, Q_MOD) for l in range(1, L)})


def verify(keys: ZkdlKeys, proof: ZkdlProof, transcript: Transcript,
           trace: list | None = None) -> bool:
    """Trusted-verifier side of Protocol 2. Returns accept/reject.

    If ``trace`` is a list, the name of the first failing check is appended
    (debugging/telemetry; does not affect soundness).
    """

    def fail(reason: str) -> bool:
        if trace is not None:
            trace.append(reason)
        return False
    cfg = keys.cfg
    L, B, d = cfg.n_layers, cfg.batch, cfg.width
    lb, ld, ll = _log2(B), _log2(d), _log2(cfg.l_pad)
    t = transcript
    op = proof.openings
    t.absorb_ints(b"coms", proof.coms.as_ints())
    ch = _Challenges.draw(t, lb, ld, ll)
    t.absorb_ints(b"op1", [op[k] for k in ("a1", "a2", "a3", "a4", "a5", "a6")])

    ef = hexpand_point(ch.u_sf)
    eb = hexpand_point(ch.u_sb)
    ew = hexpand_point(ch.u_sw)
    qb, rb = cfg.q_bits, cfg.r_bits
    two_r = pow(2, rb, Q_MOD)
    two_qr1 = pow(2, qb + rb - 1, Q_MOD)
    two_q1 = pow(2, qb - 1, Q_MOD)

    try:
        # forward sumcheck
        claim_fwd = (two_r * op["a1"] - two_qr1 * op["a2"] + op["a3"]) % Q_MOD
        fwd_products = [(2 * i, 2 * i + 1) for i in range(L)]
        w1, exp_fwd = sumcheck_verify(claim_fwd, proof.sc_fwd, 2, ld, t, b"fwd")
        if exp_fwd != combine_final(fwd_products, proof.fwd_finals,
                                    coefs=[ef[i] for i in range(L)]):
            return fail("fwd-final")
        t.absorb_ints(b"fwd/final", proof.fwd_finals)
        # backward sumcheck
        claim_bwd = (two_r * op["a4"] + op["a5"]) % Q_MOD
        bwd_products = [(2 * i, 2 * i + 1) for i in range(L - 1)]
        w2, exp_bwd = sumcheck_verify(claim_bwd, proof.sc_bwd, 2, ld, t, b"bwd")
        if exp_bwd != combine_final(bwd_products, proof.bwd_finals,
                                    coefs=[eb[i] for i in range(L - 1)]):
            return fail("bwd-final")
        t.absorb_ints(b"bwd/final", proof.bwd_finals)
        # gw sumcheck
        claim_gw = op["a6"]
        gw_products = [(2 * i, 2 * i + 1) for i in range(L)]
        w3, exp_gw = sumcheck_verify(claim_gw, proof.sc_gw, 2, lb, t, b"gw")
        if exp_gw != combine_final(gw_products, proof.gw_finals,
                                   coefs=[ew[i] for i in range(L)]):
            return fail("gw-final")
        t.absorb_ints(b"gw/final", proof.gw_finals)

        # anchor sumcheck
        pt_f = w1 + ch.u_r
        pt_g = ch.u_j + w3
        pt_b = w2 + ch.u_r2
        pt_w = ch.u_i + w3
        al = _AnchorCoefs.draw(t, L)
        # LHS: batched claims from the matmul sumcheck finals
        lhs = 0
        for l in range(1, L):        # A^l from fwd table of layer l+1
            lhs = (lhs + al.a1[l] * proof.fwd_finals[2 * l]) % Q_MOD
        for l in range(1, L):        # A^l from gw table of layer l+1
            lhs = (lhs + al.a2[l] * proof.gw_finals[2 * l + 1]) % Q_MOD
        for l in range(2, L):        # GZ^l from bwd (table index l-2)
            lhs = (lhs + al.g1[l] * proof.bwd_finals[2 * (l - 2)]) % Q_MOD
        for l in range(1, L):        # GZ^l from gw (table index l-1)
            lhs = (lhs + al.g2[l] * proof.gw_finals[2 * (l - 1)]) % Q_MOD
        u_star, exp_anchor = sumcheck_verify(lhs, proof.sc_anchor, 3,
                                             _log2(cfg.d_stack), t, b"anchor")
        f_oneb, f_zpp, f_gap, f_pa, f_pg = proof.anchor_finals
        if exp_anchor != (f_oneb * f_pa % Q_MOD * f_zpp
                          + f_oneb * f_pg % Q_MOD * f_gap) % Q_MOD:
            return fail("anchor-final")
        t.absorb_ints(b"anchor/final", proof.anchor_finals)
        # recompute public-table finals
        u_elem, u_layer = u_star[: lb + ld], u_star[lb + ld:]
        el = hexpand_point(u_layer)

        def wt_eval(weights: Dict[int, int]) -> int:
            return sum(w * el[i] for i, w in weights.items()) % Q_MOD

        pa_check = (wt_eval({l - 1: al.a1[l] for l in range(1, L)})
                    * heval_point_product(pt_f, u_elem)
                    + wt_eval({l - 1: al.a2[l] for l in range(1, L)})
                    * heval_point_product(pt_g, u_elem)) % Q_MOD
        pg_check = (wt_eval({l - 1: al.g1[l] for l in range(2, L)})
                    * heval_point_product(pt_b, u_elem)
                    + wt_eval({l - 1: al.g2[l] for l in range(1, L)})
                    * heval_point_product(pt_w, u_elem)) % Q_MOD
        if f_pa != pa_check or f_pg != pg_check:
            return fail("anchor-public-tables")

        t.absorb_ints(b"op2", [op["a7"], op["a8"]])
        upp = t.challenge_int(b"upp", Q_MOD)
        u_relu = u_star + [upp]
        v = ((1 - upp) * f_zpp + upp * f_gap) % Q_MOD
        v_q1 = (1 - f_oneb) % Q_MOD
        v_r = ((1 - upp) * op["a7"] + upp * op["a8"]) % Q_MOD
        t.absorb_ints(b"vclaims", [v, v_q1, v_r])
        t.absorb_ints(b"op3", [op[k] for k in ("zL_b", "bL_b", "y_b",
                                               "zL_w", "bL_w", "y_w")])

        # GZ^L linear checks (eq. 32): finals from bwd (l = L-1) and gw (l = L)
        gzl_b = (op["zL_b"] - two_q1 * op["bL_b"] - op["y_b"]) % Q_MOD
        if L >= 2 and proof.bwd_finals[2 * (L - 2)] != gzl_b:
            return fail("gzL-bwd")
        gzl_w = (op["zL_w"] - two_q1 * op["bL_w"] - op["y_w"]) % Q_MOD
        if proof.gw_finals[2 * (L - 1)] != gzl_w:
            return fail("gzL-gw")

        # openings
        e_pi1 = _kron(expand_point(ch.u_sf), _kron(expand_point(ch.u_r),
                                                   expand_point(ch.u_c)))
        e_pi2 = _kron(expand_point(ch.u_sb), _kron(expand_point(ch.u_r2),
                                                   expand_point(ch.u_c2)))
        e_pi3 = _kron(expand_point(ch.u_sw), _kron(expand_point(ch.u_i),
                                                   expand_point(ch.u_j)))
        e_star = expand_point(u_star)
        eL = _weight_table({L - 1: 1}, cfg.l_pad)
        b_gzl_b = _kron(eL, expand_point(pt_b))
        b_gzl_w = _kron(eL, expand_point(pt_w))

        def multi_check(name, com_int, key, claims_pts) -> bool:
            rho = t.challenge_int(b"rho/" + name.encode(), Q_MOD)
            combined_b, combined_claim, rpow = None, 0, 1
            for b_pub, claim in claims_pts:
                scaled = mont_mul(FQ, b_pub, enc(rpow)[None])
                combined_b = scaled if combined_b is None else add(FQ, combined_b, scaled)
                combined_claim = (combined_claim + rpow * claim) % Q_MOD
                rpow = rpow * rho % Q_MOD
            return ipa.open_verify(key, group.encode_group(com_int),
                                   combined_b, combined_claim,
                                   proof.ipas[name], t)

        cm = proof.coms
        if not multi_check("zpp", cm.zpp, keys.kd,
                           [(e_pi1, op["a1"]), (e_star, f_zpp),
                            (b_gzl_b, op["zL_b"]), (b_gzl_w, op["zL_w"])]):
            return fail("open-zpp")
        if not multi_check("bq", cm.bq, keys.k_bq,
                           [(e_pi1, op["a2"]), (e_star, v_q1),
                            (b_gzl_b, op["bL_b"]), (b_gzl_w, op["bL_w"])]):
            return fail("open-bq")
        if not multi_check("rz", cm.rz, keys.kd,
                           [(e_pi1, op["a3"]), (e_star, op["a7"])]):
            return fail("open-rz")
        if not multi_check("gap", cm.gap, keys.kd,
                           [(e_pi2, op["a4"]), (e_star, f_gap)]):
            return fail("open-gap")
        if not multi_check("rga", cm.rga, keys.kd,
                           [(e_pi2, op["a5"]), (e_star, op["a8"])]):
            return fail("open-rga")
        dlt = _WeightDraws.draw(t, L)
        wW1 = _weight_table({l - 1: dlt.w1[l] for l in range(1, L + 1)}, cfg.l_pad)
        wW2 = _weight_table({l: dlt.w2[l] for l in range(1, L)}, cfg.l_pad)
        b_w1 = _kron(wW1, _kron(expand_point(w1), expand_point(ch.u_c)))
        b_w2 = _kron(wW2, _kron(expand_point(ch.u_c2), expand_point(w2)))
        cl_w1 = 0
        for l in range(1, L + 1):
            cl_w1 = (cl_w1 + dlt.w1[l] * proof.fwd_finals[2 * (l - 1) + 1]) % Q_MOD
        cl_w2 = 0
        for l in range(1, L):
            cl_w2 = (cl_w2 + dlt.w2[l] * proof.bwd_finals[2 * (l - 1) + 1]) % Q_MOD
        if not multi_check("w", cm.w, keys.kw, [(b_w1, cl_w1), (b_w2, cl_w2)]):
            return fail("open-w")
        if not multi_check("gw", cm.gw, keys.kw, [(e_pi3, op["a6"])]):
            return fail("open-gw")
        if not multi_check("y", cm.y, keys.ky,
                           [(expand_point(pt_b), op["y_b"]),
                            (expand_point(pt_w), op["y_w"])]):
            return fail("open-y")
        # X openings: fold the per-sample commitments homomorphically
        for tag, row_pt, col_pt, claim in (
                ("x1", ch.u_r, w1, proof.fwd_finals[0]),
                ("x2", w3, ch.u_j, proof.gw_finals[1])):
            e_row = hexpand_point(row_pt)
            com_pts = jnp.stack([group.encode_group(ci) for ci in cm.x])
            com_fold = group.msm(com_pts, group.exps_from_ints(e_row))
            if not ipa.open_verify(keys.kx, com_fold, expand_point(col_pt),
                                   claim, proof.ipas[tag], t):
                return fail("open-" + tag)

        if not zkrelu.verify_validity(
                keys.validity, cm.validity, cm.bq, v, v_q1, v_r, u_relu,
                proof.validity, t):
            return fail("validity")
        return True
    except (ValueError, KeyError) as exc:
        return fail(f"exception: {exc!r}")


def prove_step(keys: ZkdlKeys, wit: StepWitness, rng: np.random.Generator,
               label: bytes = b"zkdl") -> ZkdlProof:
    prover = Prover(keys, rng)
    prover.commit(wit)
    return prover.prove(Transcript(label))


def verify_step(keys: ZkdlKeys, proof: ZkdlProof,
                label: bytes = b"zkdl") -> bool:
    return verify(keys, proof, Transcript(label))
