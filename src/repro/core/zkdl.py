"""Deprecated compatibility shim over `repro.core.pipeline`.

The Protocol-2 monolith that used to live here is now the staged proof
pipeline package (see `repro/core/pipeline/README.md` for the module <->
paper map).  This module keeps the original single-step API alive:
`ZkdlConfig` is a `PipelineConfig` with ``n_steps=1`` and uniform
widths, so `prove_step`/`verify_step` run a one-step `ProofSession`
over the uniform layer graph -- the T=1 single-bucket degenerate case
of the heterogeneous FAC4DNN aggregation, and the SAME witness-stacking
code path (`pipeline.witness.stack_witnesses`) as every other caller.

New code should use `repro.core.pipeline.ProofSession` directly; the
entry points below emit a `DeprecationWarning` saying so.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.core.pipeline import verifier as _verifier
from repro.core.pipeline.config import (PipelineConfig as ZkdlConfig,
                                        PipelineKeys as ZkdlKeys,
                                        make_keys)
from repro.core.pipeline.session import (AggregatedProof as ZkdlProof,
                                         SessionCommitments as ZkdlCommitments,
                                         SessionProver)
from repro.core.pipeline.tables import (dec_scalar as _dec,
                                        enc_tensor as _enc_tensor,
                                        fix_cols as _fix_cols,
                                        fix_rows as _fix_rows,
                                        kron as _kron,
                                        weight_table as _weight_table)
from repro.core.pipeline.witness import stack_witnesses
from repro.core.quantfc import StepWitness
from repro.core.transcript import Transcript

__all__ = [
    "ZkdlConfig", "ZkdlKeys", "ZkdlProof", "ZkdlCommitments",
    "make_keys", "Prover", "prove_step", "verify_step", "verify",
]


def _deprecated(name: str) -> None:
    warnings.warn(
        f"repro.core.zkdl.{name} is deprecated: use "
        "repro.core.pipeline.ProofSession (n_steps=1 reproduces the "
        "single-step protocol exactly)", DeprecationWarning, stacklevel=3)


class Prover(SessionProver):
    """Single-step prover: `commit` accepts one `StepWitness` directly."""

    def commit(self, wit: StepWitness):
        assert self.cfg.n_steps == 1, "use ProofSession for n_steps > 1"
        return super().commit(stack_witnesses([wit], self.cfg))


def verify(keys: ZkdlKeys, proof: ZkdlProof, transcript: Transcript,
           trace: list | None = None) -> bool:
    return _verifier.verify(keys, proof, transcript, trace=trace)


def prove_step(keys: ZkdlKeys, wit: StepWitness, rng: np.random.Generator,
               label: bytes = b"zkdl") -> ZkdlProof:
    _deprecated("prove_step")
    prover = Prover(keys, rng)
    prover.commit(wit)
    return prover.prove(Transcript(label))


def verify_step(keys: ZkdlKeys, proof: ZkdlProof,
                label: bytes = b"zkdl") -> bool:
    _deprecated("verify_step")
    return verify(keys, proof, Transcript(label))
