"""Quantized fully-connected network training step (Example 4.5 of zkDL).

All values are fixed-point integers at scale 2^R held in int64 numpy
arrays; the witness this module produces is exactly the set of tensors
Protocol 2 commits to and proves relations over:

    Z^l  = A^{l-1} W^l                       (30)  [scale 2^{2R}]
    A^l  = (1 - B^l) . Z''^l                 (31)  [scale 2^R]
    G_Z^L = Z^{L'} - Y                       (32)
    G_A^l = G_Z^{l+1} W^{l+1 T}              (33)  [scale 2^{2R}]
    G_W^l = G_Z^{l T} A^{l-1}                (34)  [scale 2^{2R}]
    G_Z^l = (1 - B^l) . G_A'^l               (35)

with the rescale/sign auxiliary decompositions of Section 4:

    Z^l   = 2^R Z''^l - 2^{Q+R-1} B^l + R_Z^l         (3)
    G_A^l = 2^R G_A'^l + R_GA^l                        (5)

Floor division is used for rescaling, so both remainders live in [0, 2^R)
(the paper mixes floor/round notation; floor keeps the uniqueness argument
of Theorem 4.3 intact -- see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    q_bits: int = 16     # Q: rescaled values are Q-bit signed
    r_bits: int = 8      # R: scale factor 2^R

    @property
    def scale(self) -> int:
        return 1 << self.r_bits


def quantize(x: np.ndarray, cfg: QuantConfig) -> np.ndarray:
    """Real array -> fixed-point int64 at scale 2^R, clipped to Q-bit range."""
    v = np.floor(x * cfg.scale).astype(np.int64)
    lim = 1 << (cfg.q_bits - 1)
    return np.clip(v, -lim, lim - 1)


def dequantize(v: np.ndarray, cfg: QuantConfig) -> np.ndarray:
    return v.astype(np.float64) / cfg.scale


def rescale(v: np.ndarray, cfg: QuantConfig):
    """v -> (floor(v / 2^R), remainder in [0, 2^R))."""
    vp = np.floor_divide(v, cfg.scale)
    rem = v - vp * cfg.scale
    assert (rem >= 0).all() and (rem < cfg.scale).all()
    return vp, rem


def relu_aux(z: np.ndarray, cfg: QuantConfig) -> Dict[str, np.ndarray]:
    """Decompose Z per eq. (3): returns Z', Z'', B_{Q-1}, R_Z."""
    zp, r_z = rescale(z, cfg)
    lim = 1 << (cfg.q_bits - 1)
    if (zp < -lim).any() or (zp >= lim).any():
        raise OverflowError("Z' exceeds Q-bit signed range; raise q_bits")
    b = (zp < 0).astype(np.int64)
    zpp = zp + lim * b
    assert (zpp >= 0).all() and (zpp < lim).all()
    return {"zp": zp, "zpp": zpp, "b": b, "rz": r_z}


def grad_aux(ga: np.ndarray, cfg: QuantConfig) -> Dict[str, np.ndarray]:
    """Decompose G_A per eq. (5): returns G_A', R_GA."""
    gap, r_ga = rescale(ga, cfg)
    lim = 1 << (cfg.q_bits - 1)
    if (gap < -lim).any() or (gap >= lim).any():
        raise OverflowError("G_A' exceeds Q-bit signed range; raise q_bits")
    return {"gap": gap, "rga": r_ga}


@dataclasses.dataclass
class StepWitness:
    """Every tensor of one batch update, keyed by name, values int64.

    Shapes: x (B,d), y (B,d), w[l] (d,d), and per-layer (B,d) tensors.
    ``skips`` records the residual topology the step was computed under
    (matmul layer l -> earlier activation layer j, 1-indexed): layer l's
    operand was A^{l-1} + A^j, and the backward gradients in gap/rga are
    the ACCUMULATED totals arriving at each activation (direct path plus
    every skip), which is exactly what their committed decompositions
    must cover for the split claim routing to balance.
    """
    cfg: QuantConfig
    x: np.ndarray
    y: np.ndarray
    w: List[np.ndarray]
    z: List[np.ndarray]
    zpp: List[np.ndarray]
    b: List[np.ndarray]
    rz: List[np.ndarray]
    a: List[np.ndarray]        # a[0] = x, a[l] = relu output of layer l
    gz: List[np.ndarray]       # gz[l], l = 1..L (1-indexed: gz[l-1])
    ga: List[np.ndarray]       # ga[l] for l = 1..L-1 (accumulated totals)
    gap: List[np.ndarray]
    rga: List[np.ndarray]
    gw: List[np.ndarray]
    skips: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def n_layers(self) -> int:
        return len(self.w)


def step_widths(wit: "StepWitness"):
    """The shape table d_0..d_L realized by one step witness."""
    return (wit.x.shape[1],) + tuple(w.shape[1] for w in wit.w)


def step_graph_witness(wit: "StepWitness"):
    """Graph-native view of a step witness: the layer graph implied by
    the witness shapes AND its residual topology, plus per-node named
    tensors via the op registry's witness extractors (the same
    extraction path the proof pipeline's witness stacking consumes; the
    positional lists above remain as the raw training-side carrier)."""
    from repro.core.pipeline.graph import (build_fcnn_graph,
                                           build_residual_fcnn_graph,
                                           extract_node_tensors)

    if wit.skips:
        graph = build_residual_fcnn_graph(step_widths(wit),
                                          wit.x.shape[0], wit.skips)
    else:
        graph = build_fcnn_graph(step_widths(wit), wit.x.shape[0])
    return graph, extract_node_tensors(graph, wit)


def train_step_witness(x: np.ndarray, y: np.ndarray, ws: List[np.ndarray],
                       cfg: QuantConfig,
                       skips: Dict[int, int] | None = None) -> StepWitness:
    """Forward + backward pass in exact integer arithmetic.

    ``skips`` (matmul layer l -> activation layer j, 1-indexed, with
    1 <= j <= l - 2) adds residual connections: layer l's operand is
    A^{l-1} + A^j (forward skip), and the backward pass accumulates the
    gradient of each residual sum into BOTH branches before the eq. (5)
    rescale decomposition (backward split) — gap/rga therefore decompose
    the total gradient arriving at each activation, matching the
    pipeline's claim routing onto both producer slots.
    """
    skips = dict(skips or {})
    n_layers = len(ws)
    # 0-indexed matmul m consumes a[m] (+ a[skip0[m]] on a skip)
    skip0 = {}
    for l, j in skips.items():
        if not (1 <= j <= l - 2):
            raise ValueError(f"skip {l}->{j}: need 1 <= j <= l-2")
        if ws[l - 1].shape[0] != ws[j - 1].shape[1]:
            raise ValueError(f"skip {l}->{j}: width mismatch "
                             f"{ws[l - 1].shape[0]} != {ws[j - 1].shape[1]}")
        skip0[l - 1] = j
    a = [x.astype(np.int64)]
    a_in = []                  # resolved operand of each matmul
    z, zpp, bb, rz = [], [], [], []
    for l in range(n_layers):
        op = a[-1] + a[skip0[l]] if l in skip0 else a[-1]
        a_in.append(op)
        zl = op @ ws[l]
        aux = relu_aux(zl, cfg)
        z.append(zl)
        zpp.append(aux["zpp"]); bb.append(aux["b"]); rz.append(aux["rz"])
        if l < n_layers - 1:
            a.append((1 - aux["b"]) * aux["zpp"])
    # loss layer: square loss on rescaled output, eq (32)
    zp_last = zpp[-1] - (1 << (cfg.q_bits - 1)) * bb[-1]
    gz_last = zp_last - y.astype(np.int64)

    gz = [None] * n_layers
    ga = [None] * (n_layers - 1)
    gap = [None] * (n_layers - 1)
    rga = [None] * (n_layers - 1)
    acc = [None] * n_layers    # accumulated gradient arriving at a[k]
    gz[n_layers - 1] = gz_last
    for m in range(n_layers - 1, 0, -1):
        g_in = gz[m] @ ws[m].T           # gradient wrt matmul m's operand
        acc[m] = g_in if acc[m] is None else acc[m] + g_in
        if m in skip0:                   # backward split: both branches
            j = skip0[m]
            acc[j] = g_in if acc[j] is None else acc[j] + g_in
        # all consumers of a[m] (matmul m + skips from later layers,
        # already processed) have contributed: decompose the total
        aux = grad_aux(acc[m], cfg)
        ga[m - 1] = acc[m]
        gap[m - 1] = aux["gap"]; rga[m - 1] = aux["rga"]
        gz[m - 1] = (1 - bb[m - 1]) * aux["gap"]
    gw = [gz[l].T @ a_in[l] for l in range(n_layers)]
    return StepWitness(cfg=cfg, x=a[0], y=y.astype(np.int64), w=list(ws),
                       z=z, zpp=zpp, b=bb, rz=rz, a=a, gz=gz, ga=ga,
                       gap=gap, rga=rga, gw=gw, skips=skips)


def synthetic_sgd_trajectory(n_steps: int, n_layers: int, batch: int,
                             width: int, cfg: QuantConfig, seed: int = 0,
                             lr_shift: int = 8) -> List[StepWitness]:
    """n_steps consecutive batch-update witnesses along a real integer-SGD
    trajectory on seeded synthetic data (the shared generator for tests,
    benchmarks and examples, so they all measure the same trajectory)."""
    return synthetic_sgd_trajectory_widths(
        n_steps, (width,) * (n_layers + 1), batch, cfg, seed=seed,
        lr_shift=lr_shift)


def synthetic_sgd_trajectory_widths(n_steps: int, widths, batch: int,
                                    cfg: QuantConfig, seed: int = 0,
                                    lr_shift: int = 8,
                                    skips: Dict[int, int] | None = None
                                    ) -> List[StepWitness]:
    """Heterogeneous-shape twin of `synthetic_sgd_trajectory`: ``widths``
    is the full shape table d_0..d_L (pyramid MLPs etc.), matching
    `pipeline.PipelineConfig.widths`.  The forward/backward integer
    arithmetic is shape-agnostic already; only the data generator needed
    the per-layer shapes.  ``skips`` threads the residual topology of
    `train_step_witness` through every step.  Uniform widths (without
    skips) draw the exact same seeded random streams as before, so
    existing trajectories are unchanged.
    """
    widths = tuple(int(w) for w in widths)
    rng = np.random.default_rng(seed)
    ws = [quantize(rng.uniform(-1, 1, (widths[l], widths[l + 1])) * 0.3, cfg)
          for l in range(len(widths) - 1)]
    wits = []
    for _ in range(n_steps):
        x = quantize(rng.uniform(-1, 1, (batch, widths[0])), cfg)
        y = quantize(rng.uniform(-1, 1, (batch, widths[-1])), cfg)
        wit = train_step_witness(x, y, ws, cfg, skips=skips)
        wits.append(wit)
        ws = sgd_apply(ws, wit.gw, lr_shift, cfg)
    return wits


def sgd_apply(ws: List[np.ndarray], gw: List[np.ndarray], lr_shift: int,
              cfg: QuantConfig) -> List[np.ndarray]:
    """W <- W - G_W^T / 2^{lr_shift + R}: gradient at scale 2^{2R} mapped
    back to weight scale 2^R with learning rate 2^{-lr_shift} (provable
    update: one linear relation + one range-checked remainder).

    G_W^l = G_Z^{l,T} A^{l-1} (eq. 34) is (out, in)-shaped while W^l is
    (in, out), so the update transposes -- the square uniform-width case
    masked a missing transpose here until heterogeneous shapes arrived."""
    out = []
    lim = 1 << (cfg.q_bits - 1)
    for w, g in zip(ws, gw):
        step = np.floor_divide(g, 1 << (lr_shift + cfg.r_bits)).T
        out.append(np.clip(w - step, -lim, lim - 1))
    return out
