"""SC-BD baseline: bit-decomposition range proofs via the GENERAL-PURPOSE
sumcheck backend (the comparison column of Table 2 / Figure 1).

This is the approach zkDL is measured against: each auxiliary tensor's
range requirement is proven by handing the bit-decomposition relation to a
general-purpose circuit sumcheck, eq. (36):

    aux~(u) = sum_{i,j,k} beta~(u,i) . add~(i,(j,k)) . B~(j,k) . s_k

where ``add~`` is the circuit wiring predicate connecting output element i
to its Q bit-gates.  A general-purpose backend materializes the predicate
over the full (i,(j,k)) index space, so the prover runs over THREE tables
of size D^2 Q -- the Omega(D^2 Q) proving time of Table 1 -- versus
zkReLU's O(DQ).  A separate degree-3 sumcheck proves binarity
(B .* (B-1) = 0).

The tables are honest MLE tables driven through the very same
``sumcheck_prove`` engine zkDL uses, so the comparison isolates the
PROTOCOL difference, not the arithmetic substrate.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.field import FQ, encode_i64
from repro.field import sub as fsub
from repro.core import mle
from repro.core.mle import enc_vec, expand_point, hexpand_point, hmul
from repro.core.sumcheck import (SumcheckProof, combine_final,
                                 sumcheck_prove, sumcheck_verify)
from repro.core.transcript import Transcript
from repro.core.zkrelu import bits_signed

Q_MOD = FQ.modulus


def _log2(n: int) -> int:
    assert n & (n - 1) == 0
    return n.bit_length() - 1


@dataclasses.dataclass
class ScbdProof:
    claim: int
    sc_main: SumcheckProof
    main_finals: List[int]
    sc_bin: SumcheckProof
    bin_finals: List[int]

    def size_bytes(self) -> int:
        n = 2  # claim + binary claim
        for sc in (self.sc_main, self.sc_bin):
            n += sum(len(m) for m in sc.messages)
        n += len(self.main_finals) + len(self.bin_finals)
        return 32 * n

    def proof_ints(self) -> List[int]:
        """Canonical flat integer encoding (length-prefixed sections) —
        the basis of the golden digest pin that guards the transcript
        domains against silent drift."""
        out = [self.claim]
        for sc in (self.sc_main, self.sc_bin):
            out.append(len(sc.messages))
            for msg in sc.messages:
                out.append(len(msg))
                out.extend(int(v) for v in msg)
        for finals in (self.main_finals, self.bin_finals):
            out.append(len(finals))
            out.extend(int(v) for v in finals)
        return out

    def digest(self) -> str:
        h = hashlib.sha256()
        for v in self.proof_ints():
            h.update(int(v).to_bytes(32, "little"))
        return h.hexdigest()


def _s_weights(q_bits: int) -> List[int]:
    s = [pow(2, k, Q_MOD) for k in range(q_bits - 1)]
    s.append((-pow(2, q_bits - 1, Q_MOD)) % Q_MOD)
    return s


def prove(aux: np.ndarray, q_bits: int, transcript: Transcript) -> ScbdProof:
    """Prove aux (int64, signed q_bits-bit, length D = power of 2) is in
    range, the general-purpose way: materialize the D^2 Q wiring tables."""
    d = aux.shape[0]
    ld, lq = _log2(d), _log2(q_bits)
    bits = bits_signed(aux, q_bits)               # (D, Q) in {0,1}
    t = transcript

    # --- main recomposition sumcheck over (i, j, k): index i high, k low ---
    u = t.challenge_ints(b"scbd/u", Q_MOD, ld)
    e_u = expand_point(u)                                     # (D, 4)
    claim = int(np.dot(  # host-side: <e(u), aux> mod q
        np.array([int(x) % Q_MOD for x in aux], dtype=object),
        np.array(mle_host_expand(u), dtype=object)) % Q_MOD)
    t.absorb_ints(b"scbd/claim", [claim])

    s = _s_weights(q_bits)
    bs = bits.astype(object) * np.array(s, dtype=object)[None, :]
    bs_t = enc_vec([int(x) % Q_MOD for x in bs.reshape(-1)])  # (D*Q, 4)

    # T1[i,(j,k)] = e_u[i]           (broadcast over j,k)
    t1 = jnp.broadcast_to(e_u[:, None, :], (d, d * q_bits, 4)).reshape(-1, 4)
    # T2[i,(j,k)] = eq(i, j)         (the wiring predicate, as 0/1 MLE table)
    eye = np.eye(d, dtype=np.int64)
    t2 = jnp.asarray(encode_i64(FQ, np.repeat(eye, q_bits, axis=1)
                                .reshape(-1)))
    # T3[i,(j,k)] = B[j,k] * s_k     (broadcast over i)
    t3 = jnp.broadcast_to(bs_t.reshape(1, d * q_bits, 4),
                          (d, d * q_bits, 4)).reshape(-1, 4)
    sc_main, w, main_finals = sumcheck_prove([t1, t2, t3], [(0, 1, 2)],
                                             t, b"scbd/main")

    # --- binarity sumcheck over (j, k): B .* (B - 1) = 0 -------------------
    u2 = t.challenge_ints(b"scbd/u2", Q_MOD, ld + lq)
    e2 = expand_point(u2)                                     # (D*Q, 4)
    b_t = enc_vec([int(x) for x in bits.reshape(-1)])
    one = jnp.broadcast_to(mle.enc(1), (d * q_bits, 4)).astype(jnp.uint32)
    b_minus1 = fsub(FQ, b_t, one)
    sc_bin, w2, bin_finals = sumcheck_prove([e2, b_t, b_minus1], [(0, 1, 2)],
                                            t, b"scbd/bin")
    return ScbdProof(claim, sc_main, main_finals, sc_bin, bin_finals)


def verify(proof: ScbdProof, d: int, q_bits: int,
           transcript: Transcript) -> bool:
    ld, lq = _log2(d), _log2(q_bits)
    t = transcript
    u = t.challenge_ints(b"scbd/u", Q_MOD, ld)
    t.absorb_ints(b"scbd/claim", [proof.claim])
    try:
        w, expected = sumcheck_verify(proof.claim, proof.sc_main, 3,
                                      2 * ld + lq, t, b"scbd/main")
        if expected != combine_final([(0, 1, 2)], proof.main_finals):
            return False
        t.absorb_ints(b"scbd/main/final", proof.main_finals)
        # recompute the public tables' finals: T1 = e_u (vars: k,j low; i high)
        w_k, w_j, w_i = w[:lq], w[lq:lq + ld], w[lq + ld:]
        t1_chk = mle.heval_point_product(u, w_i)
        if proof.main_finals[0] != t1_chk:
            return False
        t2_chk = mle.heval_point_product(w_i, w_j)
        if proof.main_finals[1] != t2_chk:
            return False
        # T3 final is an opening claim on the committed bits -- bound by the
        # bit commitment in a full deployment; accepted as a claim here.
        u2 = t.challenge_ints(b"scbd/u2", Q_MOD, ld + lq)
        w2, expected2 = sumcheck_verify(0, proof.sc_bin, 3, ld + lq,
                                        t, b"scbd/bin")
        if expected2 != combine_final([(0, 1, 2)], proof.bin_finals):
            return False
        t.absorb_ints(b"scbd/bin/final", proof.bin_finals)
        if proof.bin_finals[0] != mle.heval_point_product(u2, w2):
            return False
        if proof.bin_finals[2] != (proof.bin_finals[1] - 1) % Q_MOD:
            return False
        return True
    except ValueError:
        return False


def mle_host_expand(point: List[int]) -> List[int]:
    return hexpand_point(point)


def workload_elems(d: int, q_bits: int) -> int:
    """Table elements the general-purpose prover materializes (per tensor)."""
    return d * d * q_bits
