"""Fiat-Shamir transcript for the zkDL interactive protocols.

Messages are canonical python ints (standard-form field / group elements);
challenges are derived by hashing the running state with SHA-256.  Both the
prover and verifier drive an identical transcript, which makes every
interactive sumcheck / IPA below non-interactive in the random-oracle model.
"""
from __future__ import annotations

import hashlib


class Transcript:
    def __init__(self, label: bytes = b"zkdl"):
        self._state = hashlib.sha256(label).digest()
        self._counter = 0

    def absorb_bytes(self, label: bytes, data: bytes) -> None:
        h = hashlib.sha256()
        h.update(self._state)
        h.update(len(label).to_bytes(4, "little"))
        h.update(label)
        h.update(len(data).to_bytes(8, "little"))
        h.update(data)
        self._state = h.digest()

    def absorb_int(self, label: bytes, value: int) -> None:
        self.absorb_bytes(label, int(value).to_bytes(32, "little", signed=False))

    def absorb_ints(self, label: bytes, values) -> None:
        data = b"".join(int(v).to_bytes(32, "little") for v in values)
        self.absorb_bytes(label, data)

    def challenge_int(self, label: bytes, modulus: int) -> int:
        h = hashlib.sha256()
        h.update(self._state)
        h.update(b"challenge")
        h.update(len(label).to_bytes(4, "little"))
        h.update(label)
        h.update(self._counter.to_bytes(8, "little"))
        self._counter += 1
        digest = h.digest() + hashlib.sha256(h.digest()).digest()
        return int.from_bytes(digest, "little") % modulus

    def challenge_ints(self, label: bytes, modulus: int, n: int):
        return [self.challenge_int(label + b"/%d" % i, modulus) for i in range(n)]
