"""AOT executable cache: trace + lower + compile once per (fn, shapes).

The persistent XLA compilation cache (`repro.util.enable_compilation_cache`)
only skips the *backend compile* — its key is computed from the lowered
StableHLO module, so a fresh process still pays full jaxpr tracing and
MLIR lowering for every program in the prover (the dominant cost: the
pipeline is hundreds of small programs, not one big one).  This module
removes that cost end to end:

* first call per shape signature: ``jax.jit(fn).lower(*args).compile()``
  (ahead-of-time), the resulting ``Compiled`` goes into a process-wide
  registry and is serialized to disk via
  ``jax.experimental.serialize_executable``;
* later calls in the same process hit the registry (no dispatch-time
  cache probing beyond one dict lookup);
* a FRESH process deserializes the executable directly — no trace, no
  lower, no XLA compile.

Conventions for wrapped functions: dynamic arguments are positional jax
arrays, static arguments are keywords (listed in ``static_argnames``).
The cache key is (name, backend, dynamic shapes/dtypes, statics); the
proof geometry — graph spec, quantization, aggregation window T — is
fully encoded in the argument shapes, so `ProvingKey`s for different
configs can never collide in the cache.  The disk directory is keyed by
jax/jaxlib version + backend (stale entries from other versions are
never loaded), and every load failure falls back to a fresh compile.

Counters (`stats()`) make warm starts auditable: a warmed process
reports ``misses == 0`` — the cross-process "never re-traces" contract
pinned by tests/test_exec_cache.py.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import threading

_DISK_ENV = "ZKDL_EXEC_CACHE"          # path override; "off"/"0" disables disk
_MODE_ENV = "ZKDL_EXEC_MODE"           # "off" disables the whole cache
_SCHEMA = 1                            # bump to invalidate old disk layouts

_lock = threading.RLock()
_registry: dict = {}
_stats = {"hits": 0, "misses": 0, "disk_hits": 0, "disk_writes": 0,
          "disk_corrupt": 0}


def enabled() -> bool:
    return os.environ.get(_MODE_ENV, "on").lower() not in ("off", "0")


def stats() -> dict:
    with _lock:
        return dict(_stats)


def reset_stats() -> None:
    with _lock:
        for k in _stats:
            _stats[k] = 0


def clear() -> None:
    """Drop the in-process registry (disk entries stay)."""
    with _lock:
        _registry.clear()


def cache_dir() -> str | None:
    """Disk directory for serialized executables (None = disk disabled)."""
    d = os.environ.get(_DISK_ENV, "")
    if d.lower() in ("off", "0", "none"):
        return None
    if not d:
        d = os.path.join(os.path.expanduser("~"), ".cache", "zkdl-exec")
    import jax
    import jaxlib
    sub = (f"{jax.__version__}-{jaxlib.__version__}-"
           f"{jax.default_backend()}-v{_SCHEMA}")
    return os.path.join(d, sub)


def _argsig(a):
    return (tuple(a.shape), str(a.dtype))


def _key(name: str, args, statics, pos_statics=()):
    import jax
    return (name, jax.default_backend(),
            tuple(sorted(statics.items())), repr(pos_statics),
            tuple(_argsig(a) for a in args))


def _disk_path(key) -> str | None:
    base = cache_dir()
    if base is None:
        return None
    h = hashlib.sha256(repr(key).encode()).hexdigest()
    return os.path.join(base, f"{h}.exe.pkl")


def _load_or_compile(key, jitted, args, statics):
    path = _disk_path(key)
    if path is not None and os.path.exists(path):
        try:
            with open(path, "rb") as f:
                _stored_key, payload, in_tree, out_tree = pickle.load(f)
            from jax.experimental import serialize_executable as se
            comp = se.deserialize_and_load(payload, in_tree, out_tree)
            with _lock:
                _registry[key] = comp
                _stats["disk_hits"] += 1
            return comp
        except Exception:
            # stale/truncated/corrupt/foreign entry: a MISS, never an
            # error.  Count it, drop the bad file (so a crashed write or
            # bit rot can't be retried forever), recompile + rewrite.
            with _lock:
                _stats["disk_corrupt"] += 1
            try:
                os.remove(path)
            except OSError:
                pass
    # Compile with the XLA persistent cache OFF: an executable that came
    # out of that cache re-serializes WITHOUT its object-code symbols
    # (loads fine in-process, "Symbols not found" in any other process).
    # Only a genuine backend compile yields a portable serialization —
    # and this cache subsumes the persistent cache for wrapped programs
    # anyway (it also skips trace + lower, which the XLA cache cannot).
    # The use-the-cache decision is memoized process-wide on the first
    # compile (`compilation_cache.is_cache_used`), so flipping the
    # config flag alone is a no-op: reset the memo around the flip.
    import jax
    from jax._src import compilation_cache as _cc
    prev = jax.config.jax_enable_compilation_cache
    try:
        jax.config.update("jax_enable_compilation_cache", False)
        _cc.reset_cache()
        comp = jitted.lower(*args, **statics).compile()
    finally:
        jax.config.update("jax_enable_compilation_cache", prev)
        _cc.reset_cache()
    with _lock:
        _registry[key] = comp
        _stats["misses"] += 1
    if path is not None:
        try:
            from jax.experimental import serialize_executable as se
            payload, in_tree, out_tree = se.serialize(comp)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "wb") as f:
                # the key rides along so diagnostics (and bulk preloads)
                # can map a file back to its program
                pickle.dump((repr(key), payload, in_tree, out_tree), f)
            os.replace(tmp, path)
            with _lock:
                _stats["disk_writes"] += 1
        except Exception:
            pass  # serialization unsupported on this backend: memory-only
    return comp


def wrap(name: str, fn, static_argnames=(), static_argnums=()):
    """Wrap ``fn`` (pure traced jax code) in the executable cache.

    Returns a callable with the convention: dynamic args positional,
    statics keyword-only — except positions in ``static_argnums``, which
    carry hashable statics with a deterministic ``repr`` (e.g. the
    frozen-dataclass ``FieldSpec``: the field primitives take the spec
    positionally at hundreds of call sites).  With the cache disabled
    (ZKDL_EXEC_MODE=off) or a non-array dynamic argument, falls back to
    plain ``jax.jit``.
    """
    import jax
    nums = tuple(static_argnums)
    jitted = jax.jit(fn, static_argnames=tuple(static_argnames),
                     static_argnums=nums or None)

    def call(*args, **statics):
        if nums:
            pos_statics = tuple(args[i] for i in nums)
            dyn = tuple(a for i, a in enumerate(args) if i not in nums)
        else:
            pos_statics, dyn = (), args
        # nested use (this body traced inside another wrapped/jitted
        # program) must inline: a Compiled can't consume tracers
        if (not enabled()
                or any(isinstance(a, jax.core.Tracer) for a in dyn)
                or any(not hasattr(a, "shape") for a in dyn)):
            return jitted(*args, **statics)
        key = _key(name, dyn, statics, pos_statics)
        with _lock:
            comp = _registry.get(key)
        if comp is not None:
            with _lock:
                _stats["hits"] += 1
        else:
            comp = _load_or_compile(key, jitted, args, statics)
        try:
            return comp(*dyn)
        except TypeError:
            # aval mismatch the (shape, dtype) key can't see (weak types,
            # committed devices): correctness first, plain jit fallback
            return jitted(*args, **statics)

    call.__name__ = name
    call._jitted = jitted       # escape hatch (tests, parity oracles)
    return call
