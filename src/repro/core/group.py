"""The Pedersen commitment group and multi-scalar multiplication.

The group is the order-q subgroup of quadratic residues of F_p^*, with
p = 2q + 1 a Sophie-Germain pair (q is the proof field FQ).  A group
element is an FP limb array in Montgomery form; the group operation is
``mont_mul(FP, ., .)`` and exponents live in FQ.

TPU adaptation note (DESIGN.md): zkDL's CUDA prover leans on atomic bucket
accumulation for Pippenger MSM.  Atomics do not exist on the TPU vector
unit, so the MSM here is re-expressed as sort -> segmented associative
scan -> scatter of segment tails, which XLA maps onto parallel hardware
(and mirrors how production TPU kernels express histogram-like reductions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execache
from repro.field import (
    FP, FQ, GROUP_GEN, mont_mul, from_mont, encode_ints, int_to_limbs,
    limbs_to_ints, hash_to_int, pow_const,
)

P = FP.modulus
Q = FQ.modulus

WINDOW = 8            # legacy fixed window (still the large-n optimum)
NBUCKET = 1 << WINDOW


@functools.lru_cache(maxsize=None)
def best_window(n: int, nbits: int = 61) -> int:
    """Pippenger window adapted to vector length.

    Each window pass costs O(n) sort/scan work plus a 2^w-bucket
    aggregation and w squarings; small n with the fixed WINDOW=8 paid
    the full 256-bucket scatter for a handful of points.  Minimizing
    ceil(nbits/w) * (n + 2^w + w) over w picks ~log2(n), matching the
    classic analysis; the IPA's halving fold lengths are exactly the
    small-n callers that win.
    """
    best_cost, best_w = None, WINDOW
    for w in (2, 4, 8):        # divisors of 16: digits never straddle limbs
        nwin = -(-nbits // w)
        cost = nwin * (n + (1 << w) + w)
        if best_cost is None or cost < best_cost:
            best_cost, best_w = cost, w
    return best_w


def identity():
    return jnp.asarray(np.array(FP.one))


def g_mul(a, b):
    """Group operation."""
    return mont_mul(FP, a, b)


def g_inv(a):
    """Group inverse (p is prime, so a^{p-2})."""
    return pow_const(FP, a, P - 2)


def g_pow_int(base, e: int):
    """base^e for python-int exponent (e taken mod q).

    Routed through the jitted vectorized ``g_pow`` so repeated calls with
    different exponents reuse one compiled executable.
    """
    e = int(e) % Q
    exps = jnp.asarray(int_to_limbs(e))[None]
    return g_pow(base[None], exps)[0]


def g_pow(bases, exps_std, nbits: int = 61):
    """Elementwise bases^exps. exps in standard (non-Montgomery) limb form.

    Square-and-multiply as a lax.scan over the bit index so the compiled
    body is one mont_mul pair (XLA-CPU chokes on a 61x unrolled graph).
    """
    result = jnp.broadcast_to(identity(), bases.shape).astype(jnp.uint32)

    def step(carry, j):
        res, acc = carry
        limb = jnp.take(exps_std, j >> 4, axis=-1)
        bit = ((limb >> (j & 15)) & 1).astype(bool)
        res = jnp.where(bit[..., None], g_mul(res, acc), res)
        acc = g_mul(acc, acc)
        return (res, acc), None

    (result, _), _ = jax.lax.scan(step, (result, bases), jnp.arange(nbits, dtype=jnp.uint32))
    return result


g_pow = execache.wrap("g_pow", g_pow, static_argnames=("nbits",))


def _seg_combine(x, y):
    v1, f1 = x
    v2, f2 = y
    v = jnp.where(f2[..., None].astype(bool), v2, g_mul(v1, v2))
    return v, f1 | f2


def _seg_products(sp, starts, chunk: int = 32):
    """Inclusive segmented running product of (n,4) group elements with
    segment-start flags, used for the per-bucket products of the sorted
    Pippenger digits.

    For large n a flat `associative_scan` does O(n log n) group muls;
    instead the array is cut into `chunk`-length pieces: a sequential
    scan WITHIN chunks (vectorized across chunks, O(n) muls), one tiny
    associative scan over the per-chunk open-segment tails, then one
    vectorized carry-in multiply for elements before their chunk's
    first segment start.  Pure reassociation of the same products, so
    every output element is bit-identical to the flat scan."""
    n = sp.shape[0]
    one = identity()
    if n < 4 * chunk or n % chunk:
        vals, _ = jax.lax.associative_scan(_seg_combine, (sp, starts))
        return vals
    c = n // chunk
    p2 = sp.reshape(c, chunk, 4)
    f2 = starts.reshape(c, chunk)

    def step(carry, xs):
        nv, nf = _seg_combine(carry, xs)
        return (nv, nf), nv

    init = (jnp.broadcast_to(one, (c, 4)).astype(jnp.uint32),
            jnp.zeros((c,), jnp.uint32))
    (tail_v, _), vals_seq = jax.lax.scan(
        step, init, (p2.transpose(1, 0, 2), f2.T))
    vals2 = vals_seq.transpose(1, 0, 2)               # (c, chunk, 4)
    has_start = (f2.max(axis=1) > 0).astype(jnp.uint32)
    s_v, _ = jax.lax.associative_scan(_seg_combine, (tail_v, has_start))
    carry_in = jnp.concatenate(
        [jnp.broadcast_to(one, (1, 4)).astype(jnp.uint32), s_v[:-1]])
    seen = jnp.cumsum(f2, axis=1) > 0                 # start at index <= l
    fixed = jnp.where(
        seen[..., None], vals2,
        g_mul(jnp.broadcast_to(carry_in[:, None], (c, chunk, 4)), vals2))
    return fixed.reshape(n, 4)


def _msm_core(points, exps_std, nwin: int, window: int = WINDOW):
    """Pippenger MSM body; windows processed high->low inside one lax.scan
    so the compiled program contains a single window body.  ``window`` is a
    static length-adapted digit width (see `best_window`).  Pure traced
    code (no jit wrapper) so `_msm_impl` can inline it and `_msm_many_impl`
    can vmap it over a batch of independent MSMs."""
    one = identity()
    nbucket = 1 << window

    def window_body(total, w):
        bitpos = jnp.uint32(window) * w
        limb = jnp.take(exps_std, bitpos >> 4, axis=1)
        shift = bitpos & 15
        digit = (limb >> shift) & (nbucket - 1)
        if 16 % window != 0:
            # a digit may straddle the 16-bit limb boundary; the top
            # window may also run past the last limb (high bits = 0)
            nxt_idx = (bitpos >> 4) + 1
            nxt = jnp.take(exps_std, jnp.minimum(nxt_idx, 3), axis=1)
            nxt = jnp.where(nxt_idx > 3, jnp.uint32(0), nxt)
            digit = jnp.where(
                shift + window > 16,
                (digit | (nxt << (16 - shift))) & (nbucket - 1), digit)
        pts = jnp.where((digit == 0)[:, None], one[None], points)
        if points.shape[0] <= (1 << 16):
            # pack digit (< 2^window <= 2^8) and element index into one
            # uint32 key: a single flat sort + one gather replaces
            # argsort + two gathers (~4x cheaper per window on XLA-CPU,
            # and the sort runs once per window).  Equal digits keep
            # index order, but any order would do: bucket products
            # commute, so the reduction is exact either way.
            idx = jnp.arange(points.shape[0], dtype=jnp.uint32)
            skey = jnp.sort((digit << 16) | idx)
            order = skey & jnp.uint32(0xFFFF)
            sd = skey >> 16
        else:
            order = jnp.argsort(digit)
            sd = digit[order]
        sp = pts[order]
        starts = jnp.concatenate([jnp.ones((1,), jnp.uint32),
                                  (sd[1:] != sd[:-1]).astype(jnp.uint32)])
        vals = _seg_products(sp, starts)
        is_end = jnp.concatenate([(sd[1:] != sd[:-1]), jnp.ones((1,), bool)])
        idx = jnp.where(is_end, sd, jnp.uint32(nbucket))
        buckets = jnp.broadcast_to(one, (nbucket + 1, 4)).astype(jnp.uint32)
        buckets = buckets.at[idx].set(vals, mode="drop")

        # sum_j j * bucket_j via double running product, j = nbucket-1 .. 1
        def agg(carry, b):
            running, acc = carry
            running = g_mul(running, b)
            acc = g_mul(acc, running)
            return (running, acc), None

        rev = buckets[1:nbucket][::-1]
        (_, win_acc), _ = jax.lax.scan(agg, (one, one), rev)

        # total = total^(2^window) * win_acc
        def sq(t, _):
            return g_mul(t, t), None

        total, _ = jax.lax.scan(sq, total, None, length=window)
        total = g_mul(total, win_acc)
        return total, None

    ws = jnp.arange(nwin - 1, -1, -1, dtype=jnp.uint32)
    total, _ = jax.lax.scan(window_body, jnp.broadcast_to(one, (4,)).astype(jnp.uint32), ws)
    return total


def _msm_impl(points, exps_std, nwin: int, window: int = WINDOW):
    return _msm_core(points, exps_std, nwin, window)


_msm_impl = execache.wrap("msm", _msm_impl,
                          static_argnames=("nwin", "window"))


def _msm_many_impl(points, exps_std, nwin: int, window: int):
    """R independent MSMs over a shared window schedule, ONE executable.

    ``points``/``exps_std`` are (R, n, 4); the sort -> segmented-scan ->
    scatter Pippenger body is vmapped over the leading batch axis, so all
    R reductions run inside a single XLA program instead of R dispatches."""
    return jax.vmap(lambda p, e: _msm_core(p, e, nwin, window))(
        points, exps_std)


_msm_many_impl = execache.wrap("msm_many", _msm_many_impl,
                               static_argnames=("nwin", "window"))


def _pad4(n: int) -> int:
    """Next power of four >= n (fewer distinct compiled MSM shapes)."""
    m = 1
    while m < n:
        m *= 4
    return m


def msm(points, exps_std, nbits: int = 61, window: int | None = None):
    """prod_i points[i]^exps[i]; exps as (n,4) standard-form limbs.

    Power-of-two lengths run as-is; anything else pads to a power-of-four
    length with zero exponents so odd sizes reuse a handful of compiled
    executables (a pow-4 pad of an exact pow-2 input would DOUBLE the
    reduction width, and the committed tensors are all powers of two).
    The Pippenger window adapts to the (padded) length via `best_window`
    unless pinned explicitly (benchmarks compare against window=8).
    """
    n = points.shape[0]
    assert n == exps_std.shape[0]
    m = n if n & (n - 1) == 0 else _pad4(n)
    if m != n:
        points = jnp.concatenate(
            [points, jnp.broadcast_to(identity(), (m - n, 4)).astype(jnp.uint32)])
        exps_std = jnp.concatenate(
            [exps_std, jnp.zeros((m - n, 4), jnp.uint32)])
    if window is None:
        window = best_window(m, nbits)
    nwin = (nbits + window - 1) // window
    return _msm_impl(points, exps_std, nwin=nwin, window=window)


def msm_many(points, exps_std, nbits: int = 61, window: int | None = None):
    """R independent MSMs sharing one window schedule: (R, n, 4) points
    and standard-form exponents -> (R, 4) group elements.

    ``points`` may also be a single (n, 4) generator vector shared by all
    rows (the Pedersen commit-many case); it is broadcast across R.  Rows
    are padded with zero exponents to a power of TWO (the fused IPA
    rounds feed exact powers of two; `msm`'s power-of-four pad would
    double their sort width), so each row equals the sequential
    ``msm(points[r], exps[r])`` bit-for-bit while the whole batch costs
    ONE dispatch."""
    exps_std = jnp.asarray(exps_std)
    assert exps_std.ndim == 3
    r, n = exps_std.shape[0], exps_std.shape[1]
    points = jnp.asarray(points)
    if points.ndim == 2:
        points = jnp.broadcast_to(points[None], (r, n, 4))
    assert points.shape == (r, n, 4), (points.shape, exps_std.shape)
    m = max(2, 1 << (n - 1).bit_length())
    if m != n:
        points = jnp.concatenate(
            [points, jnp.broadcast_to(identity(), (r, m - n, 4)).astype(jnp.uint32)],
            axis=1)
        exps_std = jnp.concatenate(
            [exps_std, jnp.zeros((r, m - n, 4), jnp.uint32)], axis=1)
    if window is None:
        window = best_window(m, nbits)
    nwin = (nbits + window - 1) // window
    return _msm_many_impl(points, exps_std, nwin=nwin, window=window)


def msm_field(points, scalars_mont, nbits: int = 61):
    """MSM where scalars are FQ elements in Montgomery form."""
    return msm(points, from_mont(FQ, scalars_mont), nbits)


def pow_table(bases, nbits: int = 61):
    """Precomputed squaring chains: (n,4) bases -> (nbits,n,4) with
    table[j] = bases^{2^j}.  For a FIXED basis (commitment generators),
    building this once at key setup halves every later exponentiation:
    `g_pow_table` needs only the conditional multiplies, no runtime
    squarings."""
    def step(acc, _):
        return g_mul(acc, acc), acc
    _, tab = jax.lax.scan(step, bases, None, length=nbits)
    return tab


pow_table = execache.wrap("pow_table", pow_table,
                          static_argnames=("nbits",))


def g_pow_table(table, exps_std, nbits: int = 61):
    """Elementwise bases^exps via a `pow_table`: one conditional multiply
    per bit (half the work of `g_pow`'s square-and-multiply).  Exponents
    in standard limb form; bit-identical to `g_pow` on the same bases."""
    result = jnp.broadcast_to(identity(),
                              table.shape[1:]).astype(jnp.uint32)

    def step(res, xs):
        j, tab_j = xs
        limb = jnp.take(exps_std, j >> 4, axis=-1)
        bit = ((limb >> (j & 15)) & 1).astype(bool)
        return jnp.where(bit[..., None], g_mul(res, tab_j), res), None

    result, _ = jax.lax.scan(
        step, result, (jnp.arange(nbits, dtype=jnp.uint32), table))
    return result


g_pow_table = execache.wrap("g_pow_table", g_pow_table,
                            static_argnames=("nbits",))


def tree_prod(elems):
    """Product of all group elements in (n,4)."""
    one = identity()
    while elems.shape[0] > 1:
        if elems.shape[0] % 2 == 1:
            elems = jnp.concatenate([elems, one[None]], axis=0)
        elems = g_mul(elems[0::2], elems[1::2])
    return elems[0]


tree_prod = execache.wrap("tree_prod", tree_prod)


def msm_bits(points, bits):
    """prod points[i]^{bits[i]} for a 0/1 vector: pure selection product."""
    bits = jnp.asarray(bits).astype(bool)
    n = bits.shape[0]
    m = _pad4(n)
    sel = jnp.where(bits[:, None], points[:n], identity()[None])
    if m != n:
        sel = jnp.concatenate(
            [sel, jnp.broadcast_to(identity(), (m - n, 4)).astype(jnp.uint32)])
    return tree_prod(sel)


# ---------------------------------------------------------------------------
# Generators (nothing-up-my-sleeve, unknown discrete logs).
# ---------------------------------------------------------------------------

_GEN_CACHE: dict = {}


def derive_generators(label: bytes, n: int):
    """n independent subgroup generators; hash-to-group (t -> t^2 mod p).

    The per-generator hash is inherently sequential (one SHA-256 each),
    but the square / Montgomery-lift / limb-packing all run as batched
    numpy object-array ops instead of a per-generator Python loop."""
    cached = _GEN_CACHE.get(label)
    if cached is not None and cached.shape[0] >= n:
        return jnp.asarray(cached[:n])
    ts = np.array([max(hash_to_int(label + i.to_bytes(8, "little"), P), 2)
                   for i in range(n)], dtype=object)
    gm = (ts * ts % P) * pow(2, 64, P) % P   # square -> QR, then Montgomery
    out = ints_to_limbs_np(gm)
    _GEN_CACHE[label] = out
    return jnp.asarray(out)


def group_gen():
    """The canonical subgroup generator h=4 in Montgomery form."""
    g = (GROUP_GEN * pow(2, 64, P)) % P
    return jnp.asarray(int_to_limbs(g))


def decode_group(a) -> int:
    """Group element -> canonical python int (for transcripts/serialization)."""
    std = np.asarray(from_mont(FP, jnp.asarray(a)))
    return int(limbs_to_ints(std)[()])


def decode_group_many(a) -> list:
    """(R, 4) group elements -> list of R python ints, ONE host transfer."""
    std = np.asarray(from_mont(FP, jnp.asarray(a)))
    return [int(v) for v in limbs_to_ints(std)]


def encode_group(x: int):
    gm = (x % P) * pow(2, 64, P) % P
    return jnp.asarray(int_to_limbs(gm))


def exps_from_ints(vals) -> jnp.ndarray:
    """Python ints (mod q) -> standard-form limb array for msm/g_pow.

    Values already reduced into int64 range (the common case: transcript
    challenges and fold coefficients are canonical field elements) skip
    the mod; everything routes through the field's vectorized
    `ints_to_limbs` packer."""
    arr = np.asarray(list(vals), dtype=object)
    try:
        a64 = arr.astype(np.int64)
        if (a64 >= 0).all() and (a64 < Q).all():
            return jnp.asarray(ints_to_limbs_np(a64))
    except (OverflowError, TypeError):
        pass
    return jnp.asarray(ints_to_limbs_np(arr % Q))


def ints_to_limbs_np(arr: np.ndarray) -> np.ndarray:
    from repro.field import ints_to_limbs
    return ints_to_limbs(arr)
