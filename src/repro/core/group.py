"""The Pedersen commitment group and multi-scalar multiplication.

The group is the order-q subgroup of quadratic residues of F_p^*, with
p = 2q + 1 a Sophie-Germain pair (q is the proof field FQ).  A group
element is an FP limb array in Montgomery form; the group operation is
``mont_mul(FP, ., .)`` and exponents live in FQ.

TPU adaptation note (DESIGN.md): zkDL's CUDA prover leans on atomic bucket
accumulation for Pippenger MSM.  Atomics do not exist on the TPU vector
unit, so the MSM here is re-expressed as sort -> segmented associative
scan -> scatter of segment tails, which XLA maps onto parallel hardware
(and mirrors how production TPU kernels express histogram-like reductions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import (
    FP, FQ, GROUP_GEN, mont_mul, from_mont, encode_ints, int_to_limbs,
    limbs_to_ints, hash_to_int,
)

P = FP.modulus
Q = FQ.modulus

WINDOW = 8
NBUCKET = 1 << WINDOW


def identity():
    return jnp.asarray(np.array(FP.one))


def g_mul(a, b):
    """Group operation."""
    return mont_mul(FP, a, b)


def g_pow_int(base, e: int):
    """base^e for python-int exponent (e taken mod q).

    Routed through the jitted vectorized ``g_pow`` so repeated calls with
    different exponents reuse one compiled executable.
    """
    e = int(e) % Q
    exps = jnp.asarray(int_to_limbs(e))[None]
    return g_pow(base[None], exps)[0]


@functools.partial(jax.jit, static_argnames=("nbits",))
def g_pow(bases, exps_std, nbits: int = 61):
    """Elementwise bases^exps. exps in standard (non-Montgomery) limb form.

    Square-and-multiply as a lax.scan over the bit index so the compiled
    body is one mont_mul pair (XLA-CPU chokes on a 61x unrolled graph).
    """
    result = jnp.broadcast_to(identity(), bases.shape).astype(jnp.uint32)

    def step(carry, j):
        res, acc = carry
        limb = jnp.take(exps_std, j >> 4, axis=-1)
        bit = ((limb >> (j & 15)) & 1).astype(bool)
        res = jnp.where(bit[..., None], g_mul(res, acc), res)
        acc = g_mul(acc, acc)
        return (res, acc), None

    (result, _), _ = jax.lax.scan(step, (result, bases), jnp.arange(nbits, dtype=jnp.uint32))
    return result


def _seg_combine(x, y):
    v1, f1 = x
    v2, f2 = y
    v = jnp.where(f2[..., None].astype(bool), v2, g_mul(v1, v2))
    return v, f1 | f2


@functools.partial(jax.jit, static_argnames=("nwin",))
def _msm_impl(points, exps_std, nwin: int):
    """Pippenger MSM; windows processed high->low inside one lax.scan so
    the compiled program contains a single window body."""
    one = identity()

    def window_body(total, w):
        bitpos = jnp.uint32(WINDOW) * w
        limb = jnp.take(exps_std, bitpos >> 4, axis=1)
        digit = (limb >> (bitpos & 15)) & (NBUCKET - 1)
        pts = jnp.where((digit == 0)[:, None], one[None], points)
        order = jnp.argsort(digit)
        sd = digit[order]
        sp = pts[order]
        starts = jnp.concatenate([jnp.ones((1,), jnp.uint32),
                                  (sd[1:] != sd[:-1]).astype(jnp.uint32)])
        vals, _ = jax.lax.associative_scan(_seg_combine, (sp, starts))
        is_end = jnp.concatenate([(sd[1:] != sd[:-1]), jnp.ones((1,), bool)])
        idx = jnp.where(is_end, sd, jnp.uint32(NBUCKET))
        buckets = jnp.broadcast_to(one, (NBUCKET + 1, 4)).astype(jnp.uint32)
        buckets = buckets.at[idx].set(vals, mode="drop")

        # sum_j j * bucket_j via double running product, j = NBUCKET-1 .. 1
        def agg(carry, b):
            running, acc = carry
            running = g_mul(running, b)
            acc = g_mul(acc, running)
            return (running, acc), None

        rev = buckets[1:NBUCKET][::-1]
        (_, win_acc), _ = jax.lax.scan(agg, (one, one), rev)

        # total = total^(2^WINDOW) * win_acc
        def sq(t, _):
            return g_mul(t, t), None

        total, _ = jax.lax.scan(sq, total, None, length=WINDOW)
        total = g_mul(total, win_acc)
        return total, None

    ws = jnp.arange(nwin - 1, -1, -1, dtype=jnp.uint32)
    total, _ = jax.lax.scan(window_body, jnp.broadcast_to(one, (4,)).astype(jnp.uint32), ws)
    return total


def _pad4(n: int) -> int:
    """Next power of four >= n (fewer distinct compiled MSM shapes)."""
    m = 1
    while m < n:
        m *= 4
    return m


def msm(points, exps_std, nbits: int = 61):
    """prod_i points[i]^exps[i]; exps as (n,4) standard-form limbs.

    Inputs are padded to a power-of-four length with zero exponents so the
    halving shapes of the IPA reuse a handful of compiled executables.
    """
    n = points.shape[0]
    assert n == exps_std.shape[0]
    m = _pad4(n)
    if m != n:
        points = jnp.concatenate(
            [points, jnp.broadcast_to(identity(), (m - n, 4)).astype(jnp.uint32)])
        exps_std = jnp.concatenate(
            [exps_std, jnp.zeros((m - n, 4), jnp.uint32)])
    nwin = (nbits + WINDOW - 1) // WINDOW
    return _msm_impl(points, exps_std, nwin)


def msm_field(points, scalars_mont, nbits: int = 61):
    """MSM where scalars are FQ elements in Montgomery form."""
    return msm(points, from_mont(FQ, scalars_mont), nbits)


@jax.jit
def tree_prod(elems):
    """Product of all group elements in (n,4)."""
    one = identity()
    while elems.shape[0] > 1:
        if elems.shape[0] % 2 == 1:
            elems = jnp.concatenate([elems, one[None]], axis=0)
        elems = g_mul(elems[0::2], elems[1::2])
    return elems[0]


def msm_bits(points, bits):
    """prod points[i]^{bits[i]} for a 0/1 vector: pure selection product."""
    bits = jnp.asarray(bits).astype(bool)
    n = bits.shape[0]
    m = _pad4(n)
    sel = jnp.where(bits[:, None], points[:n], identity()[None])
    if m != n:
        sel = jnp.concatenate(
            [sel, jnp.broadcast_to(identity(), (m - n, 4)).astype(jnp.uint32)])
    return tree_prod(sel)


# ---------------------------------------------------------------------------
# Generators (nothing-up-my-sleeve, unknown discrete logs).
# ---------------------------------------------------------------------------

_GEN_CACHE: dict = {}


def derive_generators(label: bytes, n: int):
    """n independent subgroup generators; hash-to-group (t -> t^2 mod p)."""
    cached = _GEN_CACHE.get(label)
    if cached is not None and cached.shape[0] >= n:
        return jnp.asarray(cached[:n])
    out = np.empty((n, 4), dtype=np.uint32)
    r2 = pow(2, 128, P)
    for i in range(n):
        t = hash_to_int(label + i.to_bytes(8, "little"), P)
        if t < 2:
            t = 2
        g = (t * t) % P                      # square -> QR subgroup
        gm = (g * pow(2, 64, P)) % P         # to Montgomery form
        for j in range(4):
            out[i, j] = (gm >> (16 * j)) & 0xFFFF
    _GEN_CACHE[label] = out
    return jnp.asarray(out)


def group_gen():
    """The canonical subgroup generator h=4 in Montgomery form."""
    g = (GROUP_GEN * pow(2, 64, P)) % P
    return jnp.asarray(int_to_limbs(g))


def decode_group(a) -> int:
    """Group element -> canonical python int (for transcripts/serialization)."""
    std = np.asarray(from_mont(FP, jnp.asarray(a)))
    return int(limbs_to_ints(std)[()])


def encode_group(x: int):
    gm = (x % P) * pow(2, 64, P) % P
    return jnp.asarray(int_to_limbs(gm))


def exps_from_ints(vals) -> jnp.ndarray:
    """Python ints (mod q) -> standard-form limb array for msm/g_pow."""
    arr = np.array([int(v) % Q for v in vals], dtype=object)
    return jnp.asarray(ints_to_limbs_np(arr))


def ints_to_limbs_np(arr: np.ndarray) -> np.ndarray:
    from repro.field import ints_to_limbs
    return ints_to_limbs(arr)
