"""Multilinear-extension utilities over the proof field FQ.

Tables are flat ``(n, 4)`` uint32 limb arrays in Montgomery form with
n = 2^d.  Variable ordering is little-endian: variable j of the MLE
corresponds to bit j of the flat index, so folding variable 0 pairs
adjacent entries ``(table[2i], table[2i+1])``.

A point is a list of python ints (canonical field values, produced by the
transcript); helpers encode them to limb form on demand.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import FQ, add, sub, mont_mul, encode_int, encode_ints
from repro.core import execache

Q = FQ.modulus

# ---------------------------------------------------------------------------
# Fold backend dispatch.
#
# The sumcheck MLE fold is the memory-bound inner loop of the prover; the
# fused Pallas kernel (`repro.kernels.sumcheck_fold`) streams even/odd
# tiles through VMEM once instead of materializing diff / diff*r (3x less
# HBM traffic).  Select it with ZKDL_FOLD_BACKEND=pallas (or
# `set_fold_backend("pallas")`); off TPU the kernel runs in interpret
# mode, and the default stays the pure-jnp path.
# ---------------------------------------------------------------------------

FOLD_BACKENDS = ("jnp", "pallas")
_FOLD_BACKEND_ENV = "ZKDL_FOLD_BACKEND"
_fold_backend_override: str | None = None


def fold_backend() -> str:
    """Active fold backend: override > $ZKDL_FOLD_BACKEND > "jnp"."""
    name = _fold_backend_override or os.environ.get(_FOLD_BACKEND_ENV,
                                                    "jnp").lower()
    if name not in FOLD_BACKENDS:
        raise ValueError(f"unknown fold backend {name!r}; "
                         f"choose from {FOLD_BACKENDS}")
    return name


def set_fold_backend(name: str | None) -> None:
    """Process-wide override (None restores the env/default choice)."""
    global _fold_backend_override
    if name is not None and name not in FOLD_BACKENDS:
        raise ValueError(f"unknown fold backend {name!r}; "
                         f"choose from {FOLD_BACKENDS}")
    _fold_backend_override = name


def enc(x: int):
    """Python int -> (4,) Montgomery limb jnp array."""
    return jnp.asarray(encode_int(FQ, x))


def enc_vec(xs):
    return jnp.asarray(encode_ints(FQ, np.array([int(x) for x in xs], dtype=object)))


def _fold_pair(table, r):
    even, odd = table[0::2], table[1::2]
    diff = sub(FQ, odd, even)
    return add(FQ, even, mont_mul(FQ, diff, r[None]))


_fold_pair = execache.wrap("mle_fold_pair", _fold_pair)


def fold(table, r_limbs):
    """Fix MLE variable 0 (lowest bit) at r: (n,4) -> (n/2,4).

    Dispatches to the fused Pallas kernel when the pallas backend is
    selected (interpret mode off TPU); otherwise the pure-jnp path."""
    assert table.shape[0] % 2 == 0
    if fold_backend() == "pallas":
        from repro.kernels.sumcheck_fold import fold as _pallas_fold
        return _pallas_fold(table, r_limbs)
    return _fold_pair(table, r_limbs)


def fold_jnp(table, r_limbs):
    """The pure-jnp fold, bypassing backend dispatch (parity oracle)."""
    assert table.shape[0] % 2 == 0
    return _fold_pair(table, r_limbs)


def eval_mle(table, point_ints):
    """Evaluate the MLE of `table` at `point` (list of ints, little-endian)."""
    n = table.shape[0]
    assert n == 1 << len(point_ints), (n, len(point_ints))
    for r in point_ints:
        table = fold(table, enc(r))
    return table[0]


def _extend_expand(e, u):
    # new coordinate occupies the HIGH bit so that coordinate j of the point
    # stays aligned with bit j of the flat index (little-endian convention).
    one = jnp.asarray(np.array(FQ.one))
    lo = mont_mul(FQ, e, sub(FQ, one[None], u[None]))
    hi = mont_mul(FQ, e, u[None])
    return jnp.concatenate([lo, hi], axis=0)


_extend_expand = execache.wrap("mle_extend_expand", _extend_expand)


def expand_point(point_ints):
    """e(u): (2^d, 4) table with e_i = prod_j (u_j if bit_j(i) else 1-u_j)."""
    e = jnp.asarray(np.array(FQ.one))[None]
    for u in point_ints:
        e = _extend_expand(e, enc(u))
    return e


def _sum_step(table):
    if table.shape[0] % 2 == 1:
        table = jnp.concatenate([table, jnp.zeros((1, 4), jnp.uint32)], axis=0)
    return add(FQ, table[0::2], table[1::2])


_sum_step = execache.wrap("mle_sum_step", _sum_step)


def fsum(table):
    """Field sum of all rows of (n,4): returns (4,)."""
    while table.shape[0] > 1:
        table = _sum_step(table)
    return table[0]


def fdot(a, b):
    """Inner product of two (n,4) tables: returns (4,)."""
    return fsum(mont_mul(FQ, a, b))


def weighted_sum(tables, coefs):
    """sum_k coefs[k] * tables[k] for (k,n,4) tables and (k,4) coefs.

    ONE dispatch replacing the per-term eager mont_mul/add loop of the
    claim-folding paths (IPA multi-claim combine, the per-sample data
    fold): the scale runs elementwise and the k-axis reduces as a
    halving tree, all inside a single executable."""
    acc = mont_mul(FQ, tables, coefs[:, None, :])
    while acc.shape[0] > 1:
        if acc.shape[0] % 2 == 1:
            acc = jnp.concatenate(
                [acc, jnp.zeros((1,) + acc.shape[1:], jnp.uint32)], axis=0)
        acc = add(FQ, acc[0::2], acc[1::2])
    return acc[0]


weighted_sum = execache.wrap("mle_weighted_sum", weighted_sum)


_fdot_many_impl = execache.wrap(
    "mle_fdot_many", jax.vmap(fdot, in_axes=(None, 0)))


def fdot_many(table, bases):
    """<table, bases[k]> for each k: (n,4) x (k,n,4) -> (k,4) in ONE
    dispatch (the per-step opening claims all evaluate the same stacked
    tensor against a batch of public bases).  Just `fdot` vmapped over
    the bases, so the reduction tree stays the shared `fsum` one."""
    return _fdot_many_impl(table, bases)


# ---------------------------------------------------------------------------
# Host-side (verifier) modular arithmetic over FQ as python ints.
# ---------------------------------------------------------------------------

def hadd(x, y):
    return (x + y) % Q


def hsub(x, y):
    return (x - y) % Q


def hmul(x, y):
    return (x * y) % Q


def hinv(x):
    return pow(x, Q - 2, Q)


def hneg(x):
    return (-x) % Q


def heval_point_product(point_a, point_b):
    """beta~(a, b) = prod_j (a_j b_j + (1-a_j)(1-b_j)) for int points."""
    acc = 1
    for a, b in zip(point_a, point_b):
        acc = acc * ((a * b + (1 - a) * (1 - b)) % Q) % Q
    return acc % Q


def hexpand_point(point_ints):
    """Host e(u) as python-int list (small points only)."""
    e = [1]
    for u in point_ints:
        lo = [(x * (1 - u)) % Q for x in e]
        hi = [(x * u) % Q for x in e]
        e = lo + hi
    return e


def lagrange_eval(ys, x):
    """Evaluate the degree-(k-1) poly through points (0..k-1, ys) at x (ints)."""
    k = len(ys)
    acc = 0
    for i in range(k):
        num, den = 1, 1
        for j in range(k):
            if i == j:
                continue
            num = num * ((x - j) % Q) % Q
            den = den * ((i - j) % Q) % Q
        acc = (acc + ys[i] * num % Q * hinv(den)) % Q
    return acc
