"""jit'd public wrappers for the fused fold kernels: the sumcheck
variable-0 fold, the IPA two-coefficient halves fold, and the IPA
generator fold (fused lo^{e_lo} * hi^{e_hi})."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.field.modarith import NLIMB, FieldSpec
from repro.field import FP, FQ
from repro.kernels.limb_planes import LANE, pack_planes, unpack_planes
from repro.kernels.sumcheck_fold.kernel import (DEFAULT_BLOCK_ROWS,
                                                fold_halves_planes,
                                                fold_planes,
                                                pow_mul_planes)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def fold_planes_call(even_planes, odd_planes, r_tile, *,
                     spec: FieldSpec = FQ,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return fold_planes(even_planes, odd_planes, r_tile, spec=spec,
                       block_rows=block_rows, interpret=interpret)


def fold(table, r_limbs, *, spec: FieldSpec = FQ,
         block_rows: int | None = None, interpret: bool | None = None):
    """Drop-in for `repro.core.mle.fold`: (n,4) table, (4,) r -> (n/2,4)."""
    n = table.shape[0]
    assert n % 2 == 0 and table.shape[-1] == NLIMB
    even, odd = table[0::2], table[1::2]
    ep, _ = pack_planes(even)
    op, _ = pack_planes(odd)
    rows = ep.shape[1]
    br = block_rows or min(DEFAULT_BLOCK_ROWS, rows)
    while rows % br:
        br //= 2
    r_tile = jnp.broadcast_to(jnp.asarray(r_limbs).reshape(NLIMB, 1, 1),
                              (NLIMB, 1, LANE)).astype(jnp.uint32)
    out = fold_planes_call(ep, op, r_tile, spec=spec, block_rows=br,
                           interpret=interpret)
    return unpack_planes(out, n // 2)


def _limb_tile(limbs) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.asarray(limbs).reshape(NLIMB, 1, 1),
                            (NLIMB, 1, LANE)).astype(jnp.uint32)


def _halves_planes(table):
    n = table.shape[0]
    assert n % 2 == 0 and table.shape[-1] == NLIMB
    lp, _ = pack_planes(table[: n // 2])
    hp, _ = pack_planes(table[n // 2:])
    return lp, hp, n // 2


def _block_rows(rows: int, block_rows: int | None) -> int:
    br = block_rows or min(DEFAULT_BLOCK_ROWS, rows)
    while rows % br:
        br //= 2
    return br


def fold_halves(table, c_lo_m, c_hi_m, *, spec: FieldSpec = FQ,
                block_rows: int | None = None,
                interpret: bool | None = None):
    """The IPA scalar halves fold: (n,4) table + two Montgomery-form
    (4,) coefficients -> (n/2,4) c_lo * table[:n/2] + c_hi * table[n/2:]."""
    if interpret is None:
        interpret = _interpret_default()
    lp, hp, n2 = _halves_planes(table)
    out = fold_halves_planes(lp, hp, _limb_tile(c_lo_m), _limb_tile(c_hi_m),
                             spec=spec,
                             block_rows=_block_rows(lp.shape[1], block_rows),
                             interpret=interpret)
    return unpack_planes(out, n2)


def pow_mul_halves(gens, e_lo_std, e_hi_std, *, spec: FieldSpec = FP,
                   block_rows: int | None = None,
                   interpret: bool | None = None):
    """The IPA generator fold: (n,4) group elements + two STANDARD-form
    (4,) exponents -> (n/2,4) gens[:n/2]^{e_lo} * gens[n/2:]^{e_hi}."""
    if interpret is None:
        interpret = _interpret_default()
    lp, hp, n2 = _halves_planes(gens)
    out = pow_mul_planes(lp, hp, _limb_tile(e_lo_std), _limb_tile(e_hi_std),
                         spec=spec,
                         block_rows=_block_rows(lp.shape[1], block_rows),
                         interpret=interpret)
    return unpack_planes(out, n2)
