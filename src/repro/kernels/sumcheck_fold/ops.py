"""jit'd public wrapper for the fused sumcheck fold kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.field.modarith import NLIMB, FieldSpec
from repro.field import FQ
from repro.kernels.limb_planes import LANE, pack_planes, unpack_planes
from repro.kernels.sumcheck_fold.kernel import (DEFAULT_BLOCK_ROWS,
                                                fold_planes)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def fold_planes_call(even_planes, odd_planes, r_tile, *,
                     spec: FieldSpec = FQ,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return fold_planes(even_planes, odd_planes, r_tile, spec=spec,
                       block_rows=block_rows, interpret=interpret)


def fold(table, r_limbs, *, spec: FieldSpec = FQ,
         block_rows: int | None = None, interpret: bool | None = None):
    """Drop-in for `repro.core.mle.fold`: (n,4) table, (4,) r -> (n/2,4)."""
    n = table.shape[0]
    assert n % 2 == 0 and table.shape[-1] == NLIMB
    even, odd = table[0::2], table[1::2]
    ep, _ = pack_planes(even)
    op, _ = pack_planes(odd)
    rows = ep.shape[1]
    br = block_rows or min(DEFAULT_BLOCK_ROWS, rows)
    while rows % br:
        br //= 2
    r_tile = jnp.broadcast_to(jnp.asarray(r_limbs).reshape(NLIMB, 1, 1),
                              (NLIMB, 1, LANE)).astype(jnp.uint32)
    out = fold_planes_call(ep, op, r_tile, spec=spec, block_rows=br,
                           interpret=interpret)
    return unpack_planes(out, n // 2)
