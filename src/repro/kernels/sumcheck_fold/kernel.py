"""Pallas TPU kernel: fused sumcheck MLE fold.

One sumcheck round replaces the table T (n elements) by

    T'[i] = T[2i] + (T[2i+1] - T[2i]) * r        (fix variable 0 at r)

The unfused jnp path (`repro.core.mle.fold`) materializes `diff = odd -
even` and `diff * r` separately: ~3 reads + 3 writes of n/2 elements each
(9n/2 element-moves of HBM traffic).  This kernel streams even/odd tiles
through VMEM once and writes the folded tile: 2 reads + 1 write (3n/2
moves), a 3x reduction on the dominant memory term of the proving loop --
the fold is memory-bound (the CIOS multiply is ~152 lane-ops per 48 B,
but sub+mul+add per element is cheap next to the HBM round-trips the
unfused form makes).

The scalar ``r`` is passed as a (4, 1, 128) broadcast tile (each lane of
plane j holds limb j of r) so the kernel needs no scalar-prefetch plumbing
and the same body runs in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.field.modarith import NLIMB, FieldSpec
from repro.kernels.limb_planes import (LANE, add_planes, mont_mul_planes,
                                       sub_planes)

DEFAULT_BLOCK_ROWS = 256


def _fold_body(even_ref, odd_ref, r_ref, o_ref, *, spec: FieldSpec):
    ev = [even_ref[j] for j in range(NLIMB)]
    od = [odd_ref[j] for j in range(NLIMB)]
    rl = [r_ref[j] for j in range(NLIMB)]          # (1, 128), broadcasts
    diff = sub_planes(spec, od, ev)
    out = add_planes(spec, ev, mont_mul_planes(spec, diff, rl))
    for j in range(NLIMB):
        o_ref[j] = out[j]


def _fold_halves_body(lo_ref, hi_ref, clo_ref, chi_ref, o_ref, *,
                      spec: FieldSpec):
    """out = c_lo * lo + c_hi * hi — the IPA halves fold (top-variable
    fold with two independent coefficients, unlike the sumcheck fold's
    even + (odd - even) * r form)."""
    lo = [lo_ref[j] for j in range(NLIMB)]
    hi = [hi_ref[j] for j in range(NLIMB)]
    clo = [clo_ref[j] for j in range(NLIMB)]
    chi = [chi_ref[j] for j in range(NLIMB)]
    out = add_planes(spec, mont_mul_planes(spec, lo, clo),
                     mont_mul_planes(spec, hi, chi))
    for j in range(NLIMB):
        o_ref[j] = out[j]


@functools.partial(jax.jit,
                   static_argnames=("spec", "block_rows", "interpret"))
def fold_halves_planes(lo_planes, hi_planes, clo_tile, chi_tile, *,
                       spec: FieldSpec,
                       block_rows: int = DEFAULT_BLOCK_ROWS,
                       interpret: bool = True):
    """(4,R,128) lo/hi planes + (4,1,128) coefficient tiles -> folded."""
    nl, rows, lane = lo_planes.shape
    assert nl == NLIMB and lane == LANE
    assert hi_planes.shape == lo_planes.shape
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    blk = pl.BlockSpec((NLIMB, br, LANE), lambda i: (0, i, 0))
    cblk = pl.BlockSpec((NLIMB, 1, LANE), lambda i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_fold_halves_body, spec=spec),
        grid=(rows // br,),
        in_specs=[blk, blk, cblk, cblk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(lo_planes.shape, jnp.uint32),
        interpret=interpret,
    )(lo_planes, hi_planes, clo_tile, chi_tile)


def _pow_mul_body(lo_ref, hi_ref, elo_ref, ehi_ref, o_ref, *,
                  spec: FieldSpec, nbits: int):
    """out = lo^{e_lo} * hi^{e_hi} — the IPA generator fold, fused.

    Square-and-multiply over the shared scalar exponents as a rolled
    ``fori_loop`` (one squaring + one conditional multiply per half per
    bit); the exponents arrive as (4, 1, 128) broadcast limb tiles in
    STANDARD (non-Montgomery) form, and bit j selects its limb with a
    where-chain so the body needs no dynamic ref indexing."""
    lo = [lo_ref[j] for j in range(NLIMB)]
    hi = [hi_ref[j] for j in range(NLIMB)]
    elo = [elo_ref[j] for j in range(NLIMB)]
    ehi = [ehi_ref[j] for j in range(NLIMB)]
    ones = [jnp.full_like(lo[j], jnp.uint32(spec.one[j]))
            for j in range(NLIMB)]

    def bit_at(e, j):
        limb_i, sh = j >> jnp.uint32(4), j & jnp.uint32(15)
        limb = e[NLIMB - 1]
        for k in range(NLIMB - 2, -1, -1):
            limb = jnp.where(limb_i == k, e[k], limb)
        return (((limb >> sh) & 1) != 0)

    def step(i, carry):
        res_lo, acc_lo, res_hi, acc_hi = carry
        j = jnp.uint32(i)
        b_lo, b_hi = bit_at(elo, j), bit_at(ehi, j)
        mul_lo = mont_mul_planes(spec, res_lo, acc_lo)
        mul_hi = mont_mul_planes(spec, res_hi, acc_hi)
        res_lo = [jnp.where(b_lo, mul_lo[k], res_lo[k])
                  for k in range(NLIMB)]
        res_hi = [jnp.where(b_hi, mul_hi[k], res_hi[k])
                  for k in range(NLIMB)]
        acc_lo = mont_mul_planes(spec, acc_lo, acc_lo)
        acc_hi = mont_mul_planes(spec, acc_hi, acc_hi)
        return res_lo, acc_lo, res_hi, acc_hi

    res_lo, _, res_hi, _ = jax.lax.fori_loop(
        0, nbits, step, (ones, lo, list(ones), hi))
    out = mont_mul_planes(spec, res_lo, res_hi)
    for j in range(NLIMB):
        o_ref[j] = out[j]


@functools.partial(jax.jit,
                   static_argnames=("spec", "nbits", "block_rows",
                                    "interpret"))
def pow_mul_planes(lo_planes, hi_planes, elo_tile, ehi_tile, *,
                   spec: FieldSpec, nbits: int = 61,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = True):
    """(4,R,128) lo/hi group-element planes + (4,1,128) standard-form
    exponent tiles -> (4,R,128) lo^{e_lo} * hi^{e_hi}."""
    nl, rows, lane = lo_planes.shape
    assert nl == NLIMB and lane == LANE
    assert hi_planes.shape == lo_planes.shape
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    blk = pl.BlockSpec((NLIMB, br, LANE), lambda i: (0, i, 0))
    eblk = pl.BlockSpec((NLIMB, 1, LANE), lambda i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_pow_mul_body, spec=spec, nbits=nbits),
        grid=(rows // br,),
        in_specs=[blk, blk, eblk, eblk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(lo_planes.shape, jnp.uint32),
        interpret=interpret,
    )(lo_planes, hi_planes, elo_tile, ehi_tile)


@functools.partial(jax.jit,
                   static_argnames=("spec", "block_rows", "interpret"))
def fold_planes(even_planes, odd_planes, r_tile, *, spec: FieldSpec,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True):
    """(4,R,128) even/odd planes + (4,1,128) r tile -> (4,R,128) folded."""
    nl, rows, lane = even_planes.shape
    assert nl == NLIMB and lane == LANE
    assert odd_planes.shape == even_planes.shape
    assert r_tile.shape == (NLIMB, 1, LANE)
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    grid = (rows // br,)
    blk = pl.BlockSpec((NLIMB, br, LANE), lambda i: (0, i, 0))
    rblk = pl.BlockSpec((NLIMB, 1, LANE), lambda i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_fold_body, spec=spec),
        grid=grid,
        in_specs=[blk, blk, rblk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(even_planes.shape, jnp.uint32),
        interpret=interpret,
    )(even_planes, odd_planes, r_tile)
