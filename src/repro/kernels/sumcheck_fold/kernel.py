"""Pallas TPU kernel: fused sumcheck MLE fold.

One sumcheck round replaces the table T (n elements) by

    T'[i] = T[2i] + (T[2i+1] - T[2i]) * r        (fix variable 0 at r)

The unfused jnp path (`repro.core.mle.fold`) materializes `diff = odd -
even` and `diff * r` separately: ~3 reads + 3 writes of n/2 elements each
(9n/2 element-moves of HBM traffic).  This kernel streams even/odd tiles
through VMEM once and writes the folded tile: 2 reads + 1 write (3n/2
moves), a 3x reduction on the dominant memory term of the proving loop --
the fold is memory-bound (the CIOS multiply is ~152 lane-ops per 48 B,
but sub+mul+add per element is cheap next to the HBM round-trips the
unfused form makes).

The scalar ``r`` is passed as a (4, 1, 128) broadcast tile (each lane of
plane j holds limb j of r) so the kernel needs no scalar-prefetch plumbing
and the same body runs in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.field.modarith import NLIMB, FieldSpec
from repro.kernels.limb_planes import (LANE, add_planes, mont_mul_planes,
                                       sub_planes)

DEFAULT_BLOCK_ROWS = 256


def _fold_body(even_ref, odd_ref, r_ref, o_ref, *, spec: FieldSpec):
    ev = [even_ref[j] for j in range(NLIMB)]
    od = [odd_ref[j] for j in range(NLIMB)]
    rl = [r_ref[j] for j in range(NLIMB)]          # (1, 128), broadcasts
    diff = sub_planes(spec, od, ev)
    out = add_planes(spec, ev, mont_mul_planes(spec, diff, rl))
    for j in range(NLIMB):
        o_ref[j] = out[j]


@functools.partial(jax.jit,
                   static_argnames=("spec", "block_rows", "interpret"))
def fold_planes(even_planes, odd_planes, r_tile, *, spec: FieldSpec,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True):
    """(4,R,128) even/odd planes + (4,1,128) r tile -> (4,R,128) folded."""
    nl, rows, lane = even_planes.shape
    assert nl == NLIMB and lane == LANE
    assert odd_planes.shape == even_planes.shape
    assert r_tile.shape == (NLIMB, 1, LANE)
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    grid = (rows // br,)
    blk = pl.BlockSpec((NLIMB, br, LANE), lambda i: (0, i, 0))
    rblk = pl.BlockSpec((NLIMB, 1, LANE), lambda i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_fold_body, spec=spec),
        grid=grid,
        in_specs=[blk, blk, rblk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(even_planes.shape, jnp.uint32),
        interpret=interpret,
    )(even_planes, odd_planes, r_tile)
