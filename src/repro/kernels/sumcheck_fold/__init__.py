from repro.kernels.sumcheck_fold.ops import (fold, fold_halves,  # noqa: F401
                                             fold_planes_call,
                                             pow_mul_halves)
