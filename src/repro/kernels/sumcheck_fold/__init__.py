from repro.kernels.sumcheck_fold.ops import fold, fold_planes_call  # noqa: F401
