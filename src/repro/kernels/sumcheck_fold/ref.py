"""Oracle for the sumcheck_fold kernel: the pure-jnp fold used by the
production prover (`repro.core.mle.fold_jnp` -- the dispatch-free path,
so the oracle stays independent of ZKDL_FOLD_BACKEND)."""
from __future__ import annotations

from repro.core import mle


def fold_ref(table, r_limbs):
    """(n, 4) table, (4,) r -> (n/2, 4) folded table."""
    return mle.fold_jnp(table, r_limbs)
