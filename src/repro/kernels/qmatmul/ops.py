"""Public wrappers for the exact int16 matmul kernel.

``qmatmul_partials`` is the jit'd device path: digit split, four MXU
passes, rank-1 correction sums -- everything int32-exact.  On TPU the
result stays in this digit-plane form for downstream integer work.

``qmatmul_i64`` assembles the full-precision int64 product on host
(numpy): this is the form the zkDL witness generator (`core/quantfc`)
consumes, and the form the ref oracle is checked against.  (TPUs have no
int64 lanes; the assembly weights are powers of two, so host assembly is
four shifted adds per element.)

Padding note: an int16 zero pad entry decomposes to x_hi = 0 but
x_c = -128, so the digit matmuls and correction sums are NOT zero over
padded K.  The decomposition identity still holds exactly for the padded
matrices, and A_pad @ B_pad restricted to [:M, :N] equals A @ B (the int16
pads are true zeros) -- so the assembly simply has to use the *padded* K,
which `qmatmul_partials` returns alongside the partial products.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qmatmul.kernel import (DEFAULT_BK, DEFAULT_BM, DEFAULT_BN,
                                          qmatmul_digits)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult0: int, mult1: int):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _partials_jit(a, b, bm, bn, bk, interpret):
    a_hi = (a >> 8).astype(jnp.int8)
    a_c = ((a & 0xFF) - 128).astype(jnp.int8)
    b_hi = (b >> 8).astype(jnp.int8)
    b_c = ((b & 0xFF) - 128).astype(jnp.int8)
    hh, hc, ch, cc = qmatmul_digits(a_hi, a_c, b_hi, b_c,
                                    bm=bm, bn=bn, bk=bk, interpret=interpret)
    rs_h = jnp.sum(a_hi.astype(jnp.int32), axis=1)   # (M,)
    rs_c = jnp.sum(a_c.astype(jnp.int32), axis=1)
    cs_h = jnp.sum(b_hi.astype(jnp.int32), axis=0)   # (N,)
    cs_c = jnp.sum(b_c.astype(jnp.int32), axis=0)
    return hh, hc, ch, cc, rs_h, rs_c, cs_h, cs_c


def qmatmul_partials(a, b, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                     bk: int = DEFAULT_BK, interpret: bool | None = None):
    """(M,K) x (K,N) int16 -> (digit products + correction sums, k_pad).

    Returns ((hh, hc, ch, cc, rs_h, rs_c, cs_h, cs_c), k_pad) where the
    matrices are sliced back to (M, N) / (M,) / (N,) but the correction
    sums run over the padded contraction length ``k_pad``.
    """
    if interpret is None:
        interpret = _interpret_default()
    assert a.dtype == jnp.int16 and b.dtype == jnp.int16
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    ap = _pad_to(jnp.asarray(a), bm, bk)
    bp = _pad_to(jnp.asarray(b), bk, bn)
    k_pad = ap.shape[1]
    assert k_pad <= (1 << 17), "int32 accumulator bound requires K <= 2^17"
    out = _partials_jit(ap, bp, min(bm, ap.shape[0]), min(bn, bp.shape[1]),
                        min(bk, k_pad), interpret)
    hh, hc, ch, cc, rs_h, rs_c, cs_h, cs_c = out
    return (hh[:m, :n], hc[:m, :n], ch[:m, :n], cc[:m, :n],
            rs_h[:m], rs_c[:m], cs_h[:n], cs_c[:n]), k_pad


def qmatmul_i64(a, b, **kw) -> np.ndarray:
    """Exact int64 product of two int16 matrices via the 4-pass kernel."""
    parts, k_pad = qmatmul_partials(a, b, **kw)
    hh, hc, ch, cc, rs_h, rs_c, cs_h, cs_c = parts
    hh = np.asarray(hh, dtype=np.int64)
    hc = np.asarray(hc, dtype=np.int64)
    ch = np.asarray(ch, dtype=np.int64)
    cc = np.asarray(cc, dtype=np.int64)
    out = (hh << 16) + ((hc + ch) << 8) + cc
    out += (np.asarray(rs_h, np.int64)[:, None] << 15)
    out += (np.asarray(rs_c, np.int64)[:, None] << 7)
    out += (np.asarray(cs_h, np.int64)[None, :] << 15)
    out += (np.asarray(cs_c, np.int64)[None, :] << 7)
    out += np.int64(k_pad) << 14
    return out
