from repro.kernels.qmatmul.ops import qmatmul_i64, qmatmul_partials  # noqa: F401
