"""Pallas TPU kernel: exact int16 matmul as four int8 MXU passes.

zkDL's quantized training step (Example 4.5) is built on *exact* integer
matmuls: Z = A @ W with A, W holding Q-bit (Q<=16) signed fixed-point
values and products accumulated without rounding (the witness relations
(30)/(33)/(34) must hold bit-exactly or the proof fails).  GPUs do this
with dp4a/int64 units; the TPU MXU multiplies int8 x int8 -> int32, so the
TPU-native scheme decomposes each int16 operand into two int8 digits and
recombines four MXU passes.

Digit split (both digits genuinely int8):

    x = 256 * x_hi + x_lo,  x_lo in [0,256)         (x_hi = x >> 8)
    x_lo = x_c + 128,       x_c  in [-128,128)      (x_c = x_lo - 128)

so with J the all-ones matrix:

    A @ B = 2^16 (Ah@Bh) + 2^8 (Ah@Bc + Ac@Bh) + (Ac@Bc)
          + 2^15 rowsum(Ah) + 2^7 rowsum(Ac)            [broadcast col]
          + 2^15 colsum(Bh) + 2^7 colsum(Bc)            [broadcast row]
          + 2^14 * K

The kernel computes the four int8 MXU products (exact int32 accumulation:
|prod| <= 2^14, so K <= 2^17 cannot overflow int32); the rank-1
corrections and the power-of-two recombination are cheap vector work done
in the wrapper (`ops.py`), where the final value is assembled at int64 --
on host for witness generation, or kept as digit planes on device.

Grid is (M/BM, N/BN, K/BK) with K innermost; all four accumulators live
in VMEM for the whole K loop.  VMEM at (BM,BN,BK)=(256,256,512):
    A tiles 2*256*512 B = 0.25 MiB, B tiles 0.25 MiB,
    4 int32 accumulators 4*256*256*4 B = 1.0 MiB      -- comfortably VMEM.
MXU utilization: operands are int8 so the 128x128 MXU runs at rate; the
4x pass count is the exactness price (vs. 1 bf16 pass that would round).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _qmatmul_body(ah_ref, ac_ref, bh_ref, bc_ref,
                  hh_ref, hc_ref, ch_ref, cc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        hh_ref[...] = jnp.zeros_like(hh_ref)
        hc_ref[...] = jnp.zeros_like(hc_ref)
        ch_ref[...] = jnp.zeros_like(ch_ref)
        cc_ref[...] = jnp.zeros_like(cc_ref)

    ah = ah_ref[...]
    ac = ac_ref[...]
    bh = bh_ref[...]
    bc = bc_ref[...]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.int32)
    hh_ref[...] += dot(ah, bh)
    hc_ref[...] += dot(ah, bc)
    ch_ref[...] += dot(ac, bh)
    cc_ref[...] += dot(ac, bc)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def qmatmul_digits(a_hi, a_c, b_hi, b_c, *,
                   bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   bk: int = DEFAULT_BK, interpret: bool = True):
    """Four int8 digit matrices -> four exact int32 product matrices.

    a_hi/a_c: (M, K) int8;  b_hi/b_c: (K, N) int8.
    Returns (hh, hc, ch, cc), each (M, N) int32.
    """
    m, kdim = a_hi.shape
    _, n = b_hi.shape
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)
    grid = (m // bm, n // bn, kdim // bk)
    a_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    b_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    o_shape = jax.ShapeDtypeStruct((m, n), jnp.int32)
    return pl.pallas_call(
        _qmatmul_body,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=(o_spec, o_spec, o_spec, o_spec),
        out_shape=(o_shape, o_shape, o_shape, o_shape),
        interpret=interpret,
    )(a_hi, a_c, b_hi, b_c)
