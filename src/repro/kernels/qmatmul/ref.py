"""Oracle for the qmatmul kernel: plain numpy int64 matmul (exact for
int16 operands: |prod| < 2^30, K < 2^33 before any overflow)."""
from __future__ import annotations

import numpy as np


def qmatmul_ref(a, b) -> np.ndarray:
    return np.asarray(a, dtype=np.int64) @ np.asarray(b, dtype=np.int64)
