"""Pallas TPU kernels for zkDL's compute hot spots.

Three kernels, each a ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling) + ``ops.py`` (jit'd wrapper with layout transforms) + ``ref.py``
(oracle):

* ``modmul``        -- elementwise Montgomery limb multiply, the inner
                       loop of MSM bucket products / sumcheck evaluation.
* ``sumcheck_fold`` -- fused MLE fold (one sumcheck round), memory-bound;
                       fusing sub+mul+add cuts HBM traffic 3x.
* ``qmatmul``       -- exact int16 matmul as 4 int8 MXU passes + rank-1
                       corrections (the quantized train-step matmuls of
                       Example 4.5).

All kernels validate on CPU via ``interpret=True`` (the wrappers default
to interpret mode off-TPU) against their ``ref.py`` oracles.
"""
from repro.kernels import limb_planes  # noqa: F401
