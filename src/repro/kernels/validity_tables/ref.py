"""Pure python-int reference for the validity-table construction.

The oracle both backends of `repro.kernels.validity_tables.ops` are
parity-tested against (tests/test_validity_kernel.py): one honest,
dispatch-free evaluation of the eq. (19) vectors per flat position,
entirely in canonical field integers.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.field import FQ

Q = FQ.modulus


def tables_ref(layout, k: int, z_main: int, z_rem: int,
               e_full: List[int], es: List[int]) -> Tuple[List[int],
                                                          List[int]]:
    """(a, b) canonical-int lists for a `ValidityLayout`.

    ``e_full`` is e_relu (x) e_bit per position; ``es`` is the
    z^2-scaled e_relu (x) s table (both statements concatenated, same
    order as the layout).
    """
    n = layout.vals.shape[0]
    a_out, b_out = [], []
    for p in range(n):
        bit = (int(layout.vals[p]) >> int(layout.shift[p])) & 1
        z = z_main if layout.region[p] else z_rem
        a = (bit + int(layout.kmask[p]) * k - z) % Q
        negbp = ((1 - bit) * (1 - int(layout.colmask[p]))
                 + int(layout.kpmask[p]) * k) % Q
        b = (es[p] + (z - negbp) * e_full[p]) % Q
        a_out.append(a)
        b_out.append(b)
    return a_out, b_out
