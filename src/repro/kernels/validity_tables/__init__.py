from repro.kernels.validity_tables.ops import (BACKENDS,  # noqa: F401
                                               ValidityLayout, backend,
                                               build_layout, build_tables,
                                               set_backend)
from repro.kernels.validity_tables.ref import tables_ref  # noqa: F401
