"""Backend dispatch + layout for the zkReLU validity-table kernel.

`build_layout` flattens the stacked aux tensors into per-(row, bit)
uint32 position planes once; `build_tables` then evaluates the eq. (19)
``a`` / ``b`` vectors for BOTH validity statements (main Q-bit and
remainder R-bit, concatenated) in one dispatch.

Backends mirror `repro.core.mle.fold_backend`:

* ``jnp`` (default): one fused XLA computation over (n, 4) limb arrays
  -- the fast path on CPU/GPU and the reference the kernel is
  parity-tested against.
* ``pallas``: the limb-plane kernel in `kernel.py`; interpret mode off
  TPU.  Select with ZKDL_VALIDITY_BACKEND=pallas or
  `set_backend("pallas")`.

Both are bit-identical to `ref.tables_ref` (and to each other), so the
proof transcript does not depend on the backend choice.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import FQ, add, sub, mont_mul, encode_int
from repro.field.modarith import NLIMB
from repro.kernels.limb_planes import LANE, pack_planes, unpack_planes
from repro.kernels.validity_tables.kernel import (DEFAULT_BLOCK_ROWS,
                                                 validity_tables_planes)

Q = FQ.modulus

BACKENDS = ("jnp", "pallas")
_BACKEND_ENV = "ZKDL_VALIDITY_BACKEND"
_backend_override: str | None = None


def backend() -> str:
    """Active backend: override > $ZKDL_VALIDITY_BACKEND > "jnp"."""
    name = _backend_override or os.environ.get(_BACKEND_ENV, "jnp").lower()
    if name not in BACKENDS:
        raise ValueError(f"unknown validity backend {name!r}; "
                         f"choose from {BACKENDS}")
    return name


def set_backend(name: str | None) -> None:
    """Process-wide override (None restores the env/default choice)."""
    global _backend_override
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown validity backend {name!r}; "
                         f"choose from {BACKENDS}")
    _backend_override = name


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class ValidityLayout:
    """Flat per-(row, bit) position planes, main statement then
    remainder (all (n,) uint32, n = 2 Ds (Q + R)):

    ``vals``     the packed source value whose bit this position holds
                 (two's-complement for the signed G_A' half)
    ``shift``    the bit index within ``vals``
    ``kmask``    B_{Q-1}[row] at the forced (top-half, col Q-1) slots
    ``kpmask``   1 - B_{Q-1}[row] there
    ``colmask``  1 at the forced column (the B' forced-zero column)
    ``region``   1 on the main statement, 0 on the remainder
    """
    vals: np.ndarray
    shift: np.ndarray
    kmask: np.ndarray
    kpmask: np.ndarray
    colmask: np.ndarray
    region: np.ndarray
    n_main: int
    n_rem: int


def build_layout(zpp: np.ndarray, gap: np.ndarray, bq: np.ndarray,
                 rz: np.ndarray, rga: np.ndarray, q_bits: int,
                 r_bits: int) -> ValidityLayout:
    """Stacked aux value vectors -> flat kernel layout (host, numpy)."""
    ds = zpp.shape[0]
    qb, rb = q_bits, r_bits
    assert qb < 32 and rb < 32, "values must fit uint32"
    lim = 1 << (qb - 1)
    assert (zpp >= 0).all() and (zpp < lim).all()
    assert (gap >= -lim).all() and (gap < lim).all()
    gap_u = np.where(gap < 0, gap + (1 << qb), gap)
    u_main = np.concatenate([zpp, gap_u]).astype(np.uint32)   # (2ds,)
    u_rem = np.concatenate([rz, rga]).astype(np.uint32)

    n_main, n_rem = 2 * ds * qb, 2 * ds * rb
    vals = np.concatenate([np.repeat(u_main, qb), np.repeat(u_rem, rb)])
    shift = np.concatenate([np.tile(np.arange(qb, dtype=np.uint32), 2 * ds),
                            np.tile(np.arange(rb, dtype=np.uint32), 2 * ds)])
    # the forced column (top-half rows, bit Q-1): B is 0 there by range
    # (zpp < 2^{Q-1}), B' is forced to 0, and the k-term adds B_{Q-1}
    kmask = np.zeros((2 * ds, qb), dtype=np.uint32)
    kmask[:ds, qb - 1] = bq.astype(np.uint32)
    kpmask = np.zeros((2 * ds, qb), dtype=np.uint32)
    kpmask[:ds, qb - 1] = 1 - bq.astype(np.uint32)
    colmask = np.zeros((2 * ds, qb), dtype=np.uint32)
    colmask[:ds, qb - 1] = 1
    zpad = np.zeros(n_rem, dtype=np.uint32)
    region = np.concatenate([np.ones(n_main, dtype=np.uint32), zpad])
    return ValidityLayout(
        vals=vals.astype(np.uint32), shift=shift.astype(np.uint32),
        kmask=np.concatenate([kmask.reshape(-1), zpad]),
        kpmask=np.concatenate([kpmask.reshape(-1), zpad]),
        colmask=np.concatenate([colmask.reshape(-1), zpad]),
        region=region, n_main=n_main, n_rem=n_rem)


def _tables_jnp(vals, shift, kmask, kpmask, colmask, region, e_full, es,
                one_m, k_m, zm_m, zr_m):
    """The (n, 4) limb-array evaluation of `_tables_body` (same math)."""
    bit = (vals >> shift) & jnp.uint32(1)

    def sel(mask01, scalar_m):
        return jnp.where(mask01[:, None].astype(bool), scalar_m[None],
                         jnp.uint32(0))

    zsel = jnp.where(region[:, None].astype(bool), zm_m[None], zr_m[None])
    a = sub(FQ, add(FQ, sel(bit, one_m), sel(kmask, k_m)), zsel)
    negbp = add(FQ, sel((1 - bit) * (1 - colmask), one_m),
                sel(kpmask, k_m))
    b = add(FQ, es, mont_mul(FQ, sub(FQ, zsel, negbp), e_full))
    return a, b


from repro.core import execache as _execache  # noqa: E402

_tables_jnp = _execache.wrap("vt_tables_jnp", _tables_jnp)


def _enc_tile(x: int) -> jnp.ndarray:
    limbs = np.asarray(encode_int(FQ, x), dtype=np.uint32)
    return jnp.broadcast_to(jnp.asarray(limbs).reshape(NLIMB, 1, 1),
                            (NLIMB, 1, LANE)).astype(jnp.uint32)


def _pack_flat_u32(x: np.ndarray, rows: int) -> jnp.ndarray:
    """(n,) uint32 -> (rows, 128) plane, zero-padded."""
    pad = rows * LANE - x.shape[0]
    return jnp.asarray(np.pad(x, (0, pad)).reshape(rows, LANE))


def build_tables(layout: ValidityLayout, k: int, z_main: int, z_rem: int,
                 e_full, es, *, block_rows: int | None = None,
                 interpret: bool | None = None):
    """Layout + challenges + (n, 4) Montgomery e-tables -> (a, b).

    Returns two (n, 4) Montgomery tables covering both statements
    (split them at ``layout.n_main``).  Dispatches on `backend()`.
    """
    n = layout.vals.shape[0]
    assert e_full.shape[0] == n and es.shape[0] == n
    one_m = jnp.asarray(np.asarray(FQ.one, dtype=np.uint32))
    k_m = jnp.asarray(encode_int(FQ, k))
    zm_m = jnp.asarray(encode_int(FQ, z_main))
    zr_m = jnp.asarray(encode_int(FQ, z_rem))
    if backend() == "pallas":
        if interpret is None:
            interpret = _interpret_default()
        ef_p, _ = pack_planes(e_full)
        es_p, _ = pack_planes(es)
        rows = ef_p.shape[1]
        br = block_rows or min(DEFAULT_BLOCK_ROWS, rows)
        while rows % br:
            br //= 2
        a_p, b_p = validity_tables_planes(
            _pack_flat_u32(layout.vals, rows),
            _pack_flat_u32(layout.shift, rows),
            _pack_flat_u32(layout.kmask, rows),
            _pack_flat_u32(layout.kpmask, rows),
            _pack_flat_u32(layout.colmask, rows),
            _pack_flat_u32(layout.region, rows),
            ef_p, es_p, _enc_tile(1), _enc_tile(k), _enc_tile(z_main),
            _enc_tile(z_rem), spec=FQ, block_rows=br, interpret=interpret)
        return unpack_planes(a_p, n), unpack_planes(b_p, n)
    return _tables_jnp(jnp.asarray(layout.vals), jnp.asarray(layout.shift),
                       jnp.asarray(layout.kmask), jnp.asarray(layout.kpmask),
                       jnp.asarray(layout.colmask),
                       jnp.asarray(layout.region), e_full, es,
                       one_m, k_m, zm_m, zr_m)
