"""Pallas TPU kernel: zkReLU validity-table construction.

The validity argument's hot path turns the stacked aux tensors into the
two vectors of the combined inner-product relation (eq. 19):

    a = B_k - z 1                       (B_k = B + k \\bar{B}_{Q-1})
    b = z^2 (e_relu (x) s) + (z 1 + B'_k) . (e_relu (x) e_bit)

The former host path decomposed bits in a Python loop and pushed the
matrices through object-dtype ``encode_ints`` -- a per-element CPU walk
over 2 Ds (Q + R) positions.  Here the bit decomposition IS the kernel:
each lane owns one (row, bit) position, reads its packed source value
and bit index from uint32 planes, shifts/masks the bit out and assembles
BOTH tables in a single dispatch.  The main and remainder statements
ride the same grid, distinguished per-lane by a region mask that selects
between the two z challenges.

Because every bit value, the forced B_{Q-1} column and the two masks are
0/1 integers, the field encode is a masked select of pre-encoded scalar
tiles (``ONE``, ``k``) -- no Montgomery multiply is needed to lift the
bits, only to apply ``(z - (-B'_k)) * e`` on the b side.  Scalars arrive
as (4, 1, 128) broadcast limb tiles like `sumcheck_fold`, so the same
body runs in interpret mode off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.field.modarith import NLIMB, FieldSpec
from repro.kernels.limb_planes import (LANE, add_planes, mont_mul_planes,
                                       sub_planes)

DEFAULT_BLOCK_ROWS = 256


def _tables_body(vals_ref, shift_ref, kmask_ref, kpmask_ref, colmask_ref,
                 region_ref, efull_ref, es_ref, one_ref, k_ref, zm_ref,
                 zr_ref, a_ref, b_ref, *, spec: FieldSpec):
    """One block of (row, bit) positions -> (a, b) table planes.

    Per position p:  bit = (vals >> shift) & 1,
      a = [bit] + kmask * k - z_sel
      b = es + (z_sel - ([(1-bit)(1-colmask)] + kpmask * k)) * e_full
    where [x] selects the Montgomery ONE tile when the 0/1 integer x is
    set, ``es`` arrives pre-scaled by z^2 (and kron'd with s), and z_sel
    picks the main/remainder challenge by the region mask.
    """
    bit = (vals_ref[...] >> shift_ref[...]) & jnp.uint32(1)
    km = kmask_ref[...]
    kpm = kpmask_ref[...]
    colm = colmask_ref[...]
    reg = region_ref[...].astype(bool)

    one_t = [one_ref[j] for j in range(NLIMB)]
    k_t = [k_ref[j] for j in range(NLIMB)]

    def sel(mask01, tile):
        m = mask01.astype(bool)
        return [jnp.where(m, t, jnp.uint32(0)) for t in tile]

    # z_sel: the statement's own z challenge, chosen per lane
    zsel = [jnp.where(reg, zm_ref[j], zr_ref[j]) for j in range(NLIMB)]

    # a = B_k - z 1  (bit + k on the forced column, minus z everywhere)
    a = add_planes(spec, sel(bit, one_t), sel(km, k_t))
    a = sub_planes(spec, a, zsel)

    # -B'_k = (1 - bit) off the forced column, + k (1 - B_{Q-1}) on it
    negbp = add_planes(spec, sel((1 - bit) * (1 - colm), one_t),
                       sel(kpm, k_t))
    e_full = [efull_ref[j] for j in range(NLIMB)]
    es = [es_ref[j] for j in range(NLIMB)]
    b = add_planes(spec, es,
                   mont_mul_planes(spec, sub_planes(spec, zsel, negbp),
                                   e_full))
    for j in range(NLIMB):
        a_ref[j] = a[j]
        b_ref[j] = b[j]


@functools.partial(jax.jit,
                   static_argnames=("spec", "block_rows", "interpret"))
def validity_tables_planes(vals, shift, kmask, kpmask, colmask, region,
                           efull_planes, es_planes, one_tile, k_tile,
                           zm_tile, zr_tile, *, spec: FieldSpec,
                           block_rows: int = DEFAULT_BLOCK_ROWS,
                           interpret: bool = True):
    """(R,128) uint32 position planes + (4,R,128) field planes +
    (4,1,128) scalar tiles -> ((4,R,128) a, (4,R,128) b)."""
    rows, lane = vals.shape
    assert lane == LANE
    for m in (shift, kmask, kpmask, colmask, region):
        assert m.shape == vals.shape
    assert efull_planes.shape == (NLIMB, rows, LANE)
    assert es_planes.shape == (NLIMB, rows, LANE)
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    mblk = pl.BlockSpec((br, LANE), lambda i: (i, 0))
    blk = pl.BlockSpec((NLIMB, br, LANE), lambda i: (0, i, 0))
    cblk = pl.BlockSpec((NLIMB, 1, LANE), lambda i: (0, 0, 0))
    out = jax.ShapeDtypeStruct((NLIMB, rows, LANE), jnp.uint32)
    return pl.pallas_call(
        functools.partial(_tables_body, spec=spec),
        grid=(rows // br,),
        in_specs=[mblk, mblk, mblk, mblk, mblk, mblk, blk, blk,
                  cblk, cblk, cblk, cblk],
        out_specs=(blk, blk),
        out_shape=(out, out),
        interpret=interpret,
    )(vals, shift, kmask, kpmask, colmask, region, efull_planes, es_planes,
      one_tile, k_tile, zm_tile, zr_tile)
