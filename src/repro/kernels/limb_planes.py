"""Plane-form field arithmetic shared by the Pallas kernels.

The pure-jnp reference (`repro.field.modarith`) keeps limbs in a trailing
``(..., 4)`` axis -- natural for host code, but inside a TPU kernel the
limb axis must NOT be the minor axis (it would waste 124 of 128 lanes).
The kernels therefore use *limb-major planes*: a batch of n field elements
is held as four ``(rows, 128)`` uint32 planes, one per 16-bit limb, so
every VPU lane processes a distinct element and the CIOS inner loop is a
fully-unrolled sequence of 32-bit lane ops.

The functions here operate on ``[p0, p1, p2, p3]`` lists of identically
shaped uint32 arrays and mirror ``modarith`` exactly (same bounds proof:
all partial products and accumulators < 2^32).
"""
from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp

from repro.field.modarith import NLIMB, WMASK, FieldSpec

U32 = jnp.uint32


def _split(t):
    return t & WMASK, t >> 16


def _cond_sub_planes(spec: FieldSpec, t: List) -> List:
    """5-word value < 2m -> canonical 4 limbs (plane form)."""
    pl_ = list(spec.mod_limbs) + [0]
    borrow = jnp.zeros_like(t[0])
    u = []
    for j in range(NLIMB + 1):
        d = t[j] - jnp.uint32(pl_[j]) - borrow
        u.append(d & WMASK)
        borrow = d >> 31
    keep_t = borrow.astype(bool)  # borrow out of top word => t < m
    return [jnp.where(keep_t, t[j], u[j]) for j in range(NLIMB)]


def mont_mul_planes(spec: FieldSpec, al: Sequence, bl: Sequence) -> List:
    """CIOS Montgomery product of two plane-form operands."""
    npr = jnp.uint32(spec.nprime16)
    pl_ = [jnp.uint32(x) for x in spec.mod_limbs]
    zero = jnp.zeros(jnp.broadcast_shapes(al[0].shape, bl[0].shape), U32)
    t = [zero] * (NLIMB + 2)
    for i in range(NLIMB):
        c = zero
        for j in range(NLIMB):
            acc = t[j] + al[j] * bl[i] + c
            t[j], c = _split(acc)
        acc = t[NLIMB] + c
        t[NLIMB], t[NLIMB + 1] = _split(acc)
        m = (t[0] * npr) & WMASK
        acc = t[0] + m * pl_[0]
        _, c = _split(acc)
        for j in range(1, NLIMB):
            acc = t[j] + m * pl_[j] + c
            t[j - 1], c = _split(acc)
        acc = t[NLIMB] + c
        t[NLIMB - 1], c = _split(acc)
        t[NLIMB] = t[NLIMB + 1] + c
        t[NLIMB + 1] = zero
    return _cond_sub_planes(spec, t[:NLIMB + 1])


def add_planes(spec: FieldSpec, al: Sequence, bl: Sequence) -> List:
    c = jnp.zeros(jnp.broadcast_shapes(al[0].shape, bl[0].shape), U32)
    t = []
    for j in range(NLIMB):
        acc = al[j] + bl[j] + c
        s, c = _split(acc)
        t.append(s)
    t.append(c)
    return _cond_sub_planes(spec, t)


def sub_planes(spec: FieldSpec, al: Sequence, bl: Sequence) -> List:
    borrow = jnp.zeros(jnp.broadcast_shapes(al[0].shape, bl[0].shape), U32)
    d = []
    for j in range(NLIMB):
        x = al[j] - bl[j] - borrow
        d.append(x & WMASK)
        borrow = x >> 31
    wrapped = borrow.astype(bool)
    c = jnp.zeros_like(borrow)
    e = []
    for j in range(NLIMB):
        acc = d[j] + jnp.uint32(spec.mod_limbs[j]) + c
        s, c = _split(acc)
        e.append(s)
    return [jnp.where(wrapped, e[j], d[j]) for j in range(NLIMB)]


# ---------------------------------------------------------------------------
# Host-side layout transforms: (n, 4) trailing-limb <-> (4, rows, 128) planes
# ---------------------------------------------------------------------------

LANE = 128


def pack_planes(x, rows_mult: int = 8):
    """(n, 4) uint32 -> ((4, R, 128) planes, n) with R a multiple of rows_mult.

    Zero-padding is harmless for all plane ops (0 op 0 = 0 stays canonical).
    """
    n = x.shape[0]
    rows = max(1, -(-n // LANE))
    rows = -(-rows // rows_mult) * rows_mult
    pad = rows * LANE - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    return jnp.transpose(xp, (1, 0)).reshape(NLIMB, rows, LANE), n


def unpack_planes(planes, n: int):
    """(4, R, 128) planes -> (n, 4) trailing-limb layout."""
    flat = planes.reshape(NLIMB, -1)
    return jnp.transpose(flat, (1, 0))[:n]
