"""jit'd public wrapper for the modmul kernel.

Accepts the repo-standard trailing-limb layout ``(..., 4)`` uint32
(Montgomery form), repacks to limb-major planes, runs the Pallas kernel,
and unpacks.  On non-TPU backends the kernel executes in ``interpret=True``
mode (bit-exact, Python-evaluated) so CPU validation covers the same body
that compiles for TPU.
"""
from __future__ import annotations

import jax

from repro.field.modarith import NLIMB, FieldSpec
from repro.kernels.limb_planes import pack_planes, unpack_planes
from repro.kernels.modmul.kernel import DEFAULT_BLOCK_ROWS, modmul_planes


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def modmul_planes_call(a_planes, b_planes, *, spec: FieldSpec,
                       block_rows: int = DEFAULT_BLOCK_ROWS,
                       interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return modmul_planes(a_planes, b_planes, spec=spec,
                         block_rows=block_rows, interpret=interpret)


def modmul(spec: FieldSpec, a, b, *, block_rows: int | None = None,
           interpret: bool | None = None):
    """Elementwise Montgomery product, trailing-limb layout (..., 4)."""
    shape = a.shape
    assert shape[-1] == NLIMB and b.shape == shape
    a2 = a.reshape(-1, NLIMB)
    b2 = b.reshape(-1, NLIMB)
    n = a2.shape[0]
    ap, _ = pack_planes(a2)
    bp, _ = pack_planes(b2)
    rows = ap.shape[1]
    br = block_rows or min(DEFAULT_BLOCK_ROWS, rows)
    while rows % br:
        br //= 2
    out = modmul_planes_call(ap, bp, spec=spec, block_rows=br,
                             interpret=interpret)
    return unpack_planes(out, n).reshape(shape)
