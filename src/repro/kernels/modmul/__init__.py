from repro.kernels.modmul.ops import modmul, modmul_planes_call  # noqa: F401
