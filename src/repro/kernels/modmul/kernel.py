"""Pallas TPU kernel: elementwise Montgomery modular multiply.

This is the inner loop of every proof-side hot spot (MSM bucket products,
sumcheck round evaluation, MLE folds).  One grid step loads a
``(4, BLOCK_ROWS, 128)`` tile of each operand into VMEM, runs the fully
unrolled 16-bit-limb CIOS sequence in int32 VPU lanes, and writes the
canonical product tile.

VMEM budget per step (uint32, BLOCK_ROWS=512):
    2 operands + 1 output tile : 3 * 4 * 512 * 128 * 4 B = 3.0 MiB
    CIOS temporaries (~10 planes): 10 * 512 * 128 * 4 B  = 2.5 MiB
well under the ~16 MiB/core VMEM of TPU v5e.  The multiply is
compute-bound at ~152 int32 lane-ops per element per operand-pair
(arithmetic intensity ~= 152 ops / 48 B ~ 3.2 op/B), so larger tiles only
need to cover DMA latency, not bandwidth.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro.field.modarith import NLIMB, FieldSpec
from repro.kernels.limb_planes import LANE, mont_mul_planes

DEFAULT_BLOCK_ROWS = 512


def _modmul_body(a_ref, b_ref, o_ref, *, spec: FieldSpec):
    al = [a_ref[j] for j in range(NLIMB)]
    bl = [b_ref[j] for j in range(NLIMB)]
    ol = mont_mul_planes(spec, al, bl)
    for j in range(NLIMB):
        o_ref[j] = ol[j]


@functools.partial(jax.jit,
                   static_argnames=("spec", "block_rows", "interpret"))
def modmul_planes(a_planes, b_planes, *, spec: FieldSpec,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True):
    """(4, R, 128) x (4, R, 128) -> (4, R, 128) Montgomery product."""
    nl, rows, lane = a_planes.shape
    assert nl == NLIMB and lane == LANE and b_planes.shape == a_planes.shape
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    grid = (rows // br,)
    blk = pl.BlockSpec((NLIMB, br, LANE), lambda i: (0, i, 0))
    return pl.pallas_call(
        functools.partial(_modmul_body, spec=spec),
        grid=grid,
        in_specs=[blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(a_planes.shape, a_planes.dtype),
        interpret=interpret,
    )(a_planes, b_planes)
