"""Oracle for the modmul kernel: the verified pure-jnp CIOS multiply
(`repro.field.modarith.mont_mul`) plus a python-int cross-check."""
from __future__ import annotations

import numpy as np

from repro.field import modarith
from repro.field.modarith import FieldSpec


def modmul_ref(spec: FieldSpec, a, b):
    """(n, 4) x (n, 4) Montgomery product via the pure-jnp reference."""
    return modarith.mont_mul(spec, a, b)


def modmul_pyint(spec: FieldSpec, a, b) -> np.ndarray:
    """Ground truth through python ints: decode, multiply mod m, re-encode."""
    av = modarith.decode(spec, a)
    bv = modarith.decode(spec, b)
    prod = (av * bv) % spec.modulus
    return modarith.encode_ints(spec, prod)
