"""Distributed LM training demo: the production driver on a local mesh
with fault injection, restart-from-checkpoint, and gradient compression.

Runs a reduced qwen3-family config across 8 simulated devices (this
process forces the host-platform device count BEFORE importing jax, the
same pattern the dry-run uses), trains with pjit + int8 gradient
compression, kills itself at step 12, and restarts from the checkpoint --
the full fault-tolerance path the 1000-node deployment relies on.

    PYTHONPATH=src python examples/distributed_train.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import shutil
import sys

CKPT = "/tmp/repro_dist_demo"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    from repro.launch import train as train_mod

    argv = ["--arch", "qwen3-0.6b", "--layers", "2", "--d-model", "256",
            "--steps", "20", "--seq", "128", "--global-batch", "8",
            "--mesh", "4x2", "--ckpt-dir", CKPT, "--ckpt-every", "5",
            "--compress", "int8", "--log-every", "5"]

    print("[demo] phase 1: train with an injected failure at step 12")
    try:
        train_mod.main(argv + ["--fail-at", "12"])
    except Exception as exc:                       # noqa: BLE001
        print(f"[demo] job died as planned: {exc}")

    print("[demo] phase 2: restart -- resumes from the latest checkpoint")
    rc = train_mod.main(argv)
    print("[demo] done (restarted run completed).")
    return rc


if __name__ == "__main__":
    sys.exit(main())
