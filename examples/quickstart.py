"""Quickstart: prove and verify one training step with zkDL.

Trains a small quantized FCNN for one batch update, generates the
Protocol-2 zero-knowledge proof (zkReLU + batched matmul sumchecks +
aux-validity IPA), and verifies it as the trusted verifier would.

    PYTHONPATH=src python examples/quickstart.py [--width 32] [--batch 8]
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--layers", type=int, default=2)
    args = ap.parse_args()

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.core import quantfc, zkdl
    from repro.core.quantfc import QuantConfig, train_step_witness

    cfg = zkdl.ZkdlConfig(n_layers=args.layers, batch=args.batch,
                          width=args.width, q_bits=16, r_bits=8)
    print(f"[quickstart] FCNN: {args.layers} layers x {args.width} wide, "
          f"batch {args.batch} -- Example 4.5 of the paper")

    rng = np.random.default_rng(0)
    qc = QuantConfig(q_bits=16, r_bits=8)
    x = quantfc.quantize(rng.uniform(-1, 1, (args.batch, args.width)), qc)
    y = quantfc.quantize(rng.uniform(-1, 1, (args.batch, args.width)), qc)
    ws = [quantfc.quantize(
        rng.uniform(-1, 1, (args.width, args.width)) * 0.3, qc)
        for _ in range(args.layers)]

    t0 = time.time()
    wit = train_step_witness(x, y, ws, qc)
    print(f"[quickstart] witness (exact int fwd+bwd, eqs 30-35): "
          f"{time.time()-t0:.2f}s")

    t0 = time.time()
    keys = zkdl.make_keys(cfg)
    print(f"[quickstart] commitment keys: {time.time()-t0:.2f}s")

    t0 = time.time()
    proof = zkdl.prove_step(keys, wit, rng)
    print(f"[quickstart] PROVE: {time.time()-t0:.1f}s, "
          f"proof size {proof.size_bytes()/1024:.1f} kB")

    t0 = time.time()
    ok = zkdl.verify_step(keys, proof)
    print(f"[quickstart] VERIFY: {time.time()-t0:.1f}s -> "
          f"{'ACCEPT' if ok else 'REJECT'}")
    assert ok

    # a tampered gradient must be rejected
    wit.gw[0][0, 0] += 1
    bad = zkdl.prove_step(keys, wit, rng)
    ok_bad = zkdl.verify_step(keys, bad)
    print(f"[quickstart] tampered-gradient proof -> "
          f"{'ACCEPT (!!)' if ok_bad else 'REJECT (as it must)'}")
    assert not ok_bad
    print("[quickstart] done.")


if __name__ == "__main__":
    main()
