"""Quickstart: verifiable training with the aggregated proof pipeline.

Trains a small quantized FCNN for T batch updates, aggregates them into
ONE zero-knowledge proof via `ProofSession` (zkReLU + batched matmul
sumchecks over layers AND steps + aux-validity IPA -- the FAC4DNN
aggregation), and verifies it as the trusted verifier would.

    PYTHONPATH=src python examples/quickstart.py \
        [--width 16] [--batch 4] [--agg-steps 2]
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--agg-steps", type=int, default=2,
                    help="training steps aggregated into one proof")
    args = ap.parse_args()

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory
    from repro.core.pipeline import (PipelineConfig, ProofSession,
                                     make_keys, verify_session)

    T = args.agg_steps
    cfg = PipelineConfig(n_layers=args.layers, batch=args.batch,
                         width=args.width, q_bits=16, r_bits=8, n_steps=T)
    print(f"[quickstart] FCNN: {args.layers} layers x {args.width} wide, "
          f"batch {args.batch}, {T} aggregated step(s) -- Example 4.5 + "
          f"FAC4DNN cross-step stacking")

    qc = QuantConfig(q_bits=16, r_bits=8)
    t0 = time.time()
    keys = make_keys(cfg)
    print(f"[quickstart] commitment keys: {time.time()-t0:.2f}s")

    def make_trajectory(tamper_last=False):
        wits = synthetic_sgd_trajectory(T, args.layers, args.batch,
                                        args.width, qc, seed=0)
        if tamper_last:
            wits[-1].gw[0][0, 0] += 1      # forged weight gradient
        return wits

    def prove_trajectory(wits):
        session = ProofSession(keys, np.random.default_rng(1))
        for wit in wits:
            session.add_step(wit)
        return session.prove()

    t0 = time.time()
    honest = make_trajectory()
    print(f"[quickstart] {T} witnesses (exact int fwd+bwd, eqs 30-35): "
          f"{time.time()-t0:.2f}s")

    t0 = time.time()
    proof = prove_trajectory(honest)
    print(f"[quickstart] PROVE ({T} steps, one proof): {time.time()-t0:.1f}s,"
          f" proof size {proof.size_bytes()/1024:.1f} kB "
          f"({proof.size_bytes()/1024/T:.1f} kB/step)")

    t0 = time.time()
    ok = verify_session(keys, proof)
    print(f"[quickstart] VERIFY: {time.time()-t0:.1f}s -> "
          f"{'ACCEPT' if ok else 'REJECT'}")
    assert ok

    # a tampered gradient in the LAST aggregated step must be rejected
    ok_bad = verify_session(keys, prove_trajectory(make_trajectory(
        tamper_last=True)))
    print(f"[quickstart] tampered-gradient proof -> "
          f"{'ACCEPT (!!)' if ok_bad else 'REJECT (as it must)'}")
    assert not ok_bad
    print("[quickstart] done.")


if __name__ == "__main__":
    main()
