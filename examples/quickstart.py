"""Quickstart: the graph-first compile -> prove -> verify lifecycle.

Builds a proof graph with `GraphBuilder` (optionally with a residual
skip connection), compiles it ONCE into a (ProvingKey, VerifyingKey)
pair, trains a small quantized FCNN for T batch updates, aggregates
them into ONE zero-knowledge proof via `ProofSession`, SERIALIZES the
proof to its canonical byte format, and verifies it from bytes alone —
exactly what a remote verifier holding only vk.bin would do.

    PYTHONPATH=src python examples/quickstart.py \
        [--width 16] [--batch 4] [--agg-steps 2] [--residual]
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--agg-steps", type=int, default=2,
                    help="training steps aggregated into one proof")
    ap.add_argument("--residual", action="store_true",
                    help="add a skip connection (needs >= 3 layers; "
                         "exercises the residual claim routing)")
    args = ap.parse_args()

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import (GraphBuilder, ProofSession,
                                     VerifyingKey, compile, encode_proof,
                                     graph_skips, graph_widths,
                                     verify_bytes)

    T = args.agg_steps
    layers = max(args.layers, 3) if args.residual else args.layers

    # 1. build the proof graph (the single source of truth for shapes)
    b = GraphBuilder(batch=args.batch).input(args.width)
    for l in range(1, layers + 1):
        if args.residual and l == 3:
            b.residual(to=1)               # operand of layer 3 = A^2 + A^1
        b.dense(args.width).relu()
    graph = b.output()
    shape = "x".join(str(w) for w in graph_widths(graph))
    print(f"[quickstart] graph: {shape}, batch {args.batch}, "
          f"skips {graph_skips(graph) or '{}'}, {T} aggregated step(s)")

    # 2. compile: one-time setup, reusable across sessions
    qc = QuantConfig(q_bits=16, r_bits=8)
    t0 = time.time()
    pk, vk = compile(graph, qc, n_steps=T)
    vk_bytes = vk.to_bytes()
    print(f"[quickstart] compile: {time.time()-t0:.2f}s "
          f"(vk serializes to {len(vk_bytes)} bytes)")

    def prove_trajectory(tamper_last=False):
        wits = synthetic_sgd_trajectory_widths(
            T, graph_widths(graph), args.batch, qc, seed=0,
            skips=graph_skips(graph))
        if tamper_last:
            wits[-1].gw[0][0, 0] += 1      # forged weight gradient
        session = ProofSession(pk, np.random.default_rng(1))
        for wit in wits:
            session.add_step(wit)
        return encode_proof(session.prove())

    # 3. prove: T steps -> ONE proof -> canonical bytes
    t0 = time.time()
    proof_bytes = prove_trajectory()
    print(f"[quickstart] PROVE ({T} steps, one proof): {time.time()-t0:.1f}s,"
          f" serialized {len(proof_bytes)/1024:.1f} kB "
          f"({len(proof_bytes)/1024/T:.2f} kB/step)")

    # 4. verify FROM BYTES with a vk rebuilt from bytes — no session,
    #    no prover state, exactly the remote-verifier path
    t0 = time.time()
    ok = verify_bytes(VerifyingKey.from_bytes(vk_bytes), proof_bytes)
    print(f"[quickstart] VERIFY (from serialized bytes): "
          f"{time.time()-t0:.1f}s -> {'ACCEPT' if ok else 'REJECT'}")
    assert ok

    # a tampered gradient in the LAST aggregated step must be rejected
    ok_bad = verify_bytes(vk, prove_trajectory(tamper_last=True))
    print(f"[quickstart] tampered-gradient proof -> "
          f"{'ACCEPT (!!)' if ok_bad else 'REJECT (as it must)'}")
    assert not ok_bad
    print("[quickstart] done.")


if __name__ == "__main__":
    main()
