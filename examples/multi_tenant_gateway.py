"""Multi-tenant proving gateway demo: two training jobs share one warm
prover pool, one of them misbehaves, and the other never notices.

The gateway control plane (see "Operating the gateway" in
src/repro/core/pipeline/README.md) in action:

1. one `ProvingGateway` holds the directory lock and a pool of prove
   workers; each `add_tenant` gets its own journal/manifest/vk
   namespace under ``out_dir/tenants/<name>/``;
2. tenant "alice" (weight 2) trains normally; tenant "mallory" submits
   a witness with the wrong quantization geometry — preflight rejects
   it with a typed error BEFORE anything touches disk;
3. mallory's prover is then poisoned via fault injection until her
   circuit breaker trips — she degrades to journal-only while alice's
   windows keep proving on the shared pool;
4. after the breaker's half-open trial recovers, a second gateway run
   on the same out_dir replays mallory's retained journal and commits
   everything exactly once — both tenants' proofs verify from bytes.

    PYTHONPATH=src python examples/multi_tenant_gateway.py \
        [--steps 4] [--window 2] [--out-dir /tmp/zkdl_gateway_demo]
"""
import argparse
import dataclasses
import os
import shutil
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--window", type=int, default=2)
    ap.add_argument("--widths", default="4,4,4")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--out-dir", default="/tmp/zkdl_gateway_demo")
    args = ap.parse_args()

    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import build_fcnn_graph
    from repro.core.pipeline.proofio import decode_vk
    from repro.core.pipeline.verifier import verify_bytes
    from repro.launch import serve
    from repro.launch.preflight import WitnessValidationError
    from repro.launch.serve import ProvingGateway
    from repro.train.resilience import FailureInjector

    shutil.rmtree(args.out_dir, ignore_errors=True)
    widths = tuple(int(w) for w in args.widths.split(","))
    quant = QuantConfig(q_bits=16, r_bits=4)
    graph = build_fcnn_graph(widths, batch=args.batch)
    n_windows = args.steps // args.window
    trajs = {"alice": synthetic_sgd_trajectory_widths(
                 args.steps, widths, args.batch, quant, seed=11),
             # mallory trains twice as long: the first half absorbs the
             # poison, the second half parks behind her tripped breaker
             "mallory": synthetic_sgd_trajectory_widths(
                 2 * args.steps, widths, args.batch, quant, seed=22)}

    # -- run 1: shared pool; mallory's proves fail until her breaker
    # trips (fault hits 0-2 raise inside the prove attempt)
    print("== run 1: two tenants, mallory's prover poisoned ==")
    gw = ProvingGateway(args.out_dir, n_workers=2, max_attempts=1,
                        breaker_threshold=2, breaker_reset_s=1.0,
                        injector=FailureInjector.from_spec(
                            "gateway/pre-prove@0-1"))
    gw.start()
    alice = gw.add_tenant("alice", graph, quant, n_steps=args.window,
                          weight=2.0, rng_seed=11, warm=True)
    mallory = gw.add_tenant("mallory", graph, quant, n_steps=args.window,
                            rng_seed=22)

    # preflight: a geometry-mismatched witness is rejected pre-journal
    bad = dataclasses.replace(trajs["mallory"][0],
                              cfg=QuantConfig(q_bits=8, r_bits=2))
    try:
        gw.submit("mallory", bad)
    except WitnessValidationError as exc:
        print(f"   preflight rejected mallory's witness: "
              f"{type(exc).__name__}: {exc}")
    assert mallory.stats["rejected"] == 1 and mallory.stats["journaled"] == 0

    # mallory submits alone first, so HER windows absorb the two
    # injected failures and trip her breaker
    deadline = time.monotonic() + 600
    for wit in trajs["mallory"][:args.steps]:
        gw.submit("mallory", wit)
    while mallory.stats["failed_windows"] < n_windows:
        assert time.monotonic() < deadline, "poison never fired"
        time.sleep(0.05)
    print(f"   mallory: {mallory.stats['failed_windows']} windows FAILED "
          f"-> breaker {mallory.breaker.state!r} "
          f"(trips={mallory.breaker.trips})")

    # with mallory tripped, her NEW windows park journal-only while
    # alice's train/prove loop runs undisturbed on the shared pool
    for wit in trajs["mallory"][args.steps:]:
        gw.submit("mallory", wit)
    for wit in trajs["alice"]:
        gw.submit("alice", wit)
    while alice.stats["proved"] < n_windows:
        assert time.monotonic() < deadline, "alice starved"
        time.sleep(0.05)
    print(f"   alice: {alice.stats['proved']}/{n_windows} windows proved "
          f"while mallory was degraded "
          f"(mallory deferred={mallory.stats['deferred']})")
    # mallory self-heals: the half-open trial window proves, the breaker
    # closes, and her parked windows drain
    while mallory.stats["proved"] < n_windows:
        assert time.monotonic() < deadline, "mallory never recovered"
        time.sleep(0.05)
    gw.close(timeout=600)
    print(f"   mallory recovered via half-open trial: "
          f"{mallory.stats['proved']} proved, "
          f"{mallory.stats['failed_windows']} failed (journal retained), "
          f"breaker {mallory.breaker.state!r}")

    # -- run 2: same out_dir; failed windows replay from their journals
    print("== run 2: restart, replay mallory's failed windows ==")
    gw = ProvingGateway(args.out_dir, n_workers=2)
    gw.start()
    tenants = {
        "alice": gw.add_tenant("alice", graph, quant,
                               n_steps=args.window, weight=2.0,
                               rng_seed=11),
        "mallory": gw.add_tenant("mallory", graph, quant,
                                 n_steps=args.window, rng_seed=22),
    }
    print(f"   mallory replayed {tenants['mallory'].stats['replayed']} "
          f"journaled steps")
    gw.close(timeout=600)

    # -- audit: both tenants committed exactly once, all proofs verify
    expected = {"alice": n_windows, "mallory": 2 * n_windows}
    for name, t in tenants.items():
        man = serve.read_manifest(t.dir)
        counts = serve.manifest_commit_counts(t.dir)
        with open(os.path.join(t.dir, "vk.bin"), "rb") as f:
            vk = decode_vk(f.read())
        for w in range(expected[name]):
            assert man[w]["status"] == "COMMITTED", (name, w, man.get(w))
            assert counts[w] == 1, \
                f"{name} window {w} committed {counts[w]} times"
            with open(t.proof_path(w), "rb") as f:
                assert verify_bytes(vk, f.read(), label=b"zkdl/train"), \
                    (name, w)
        assert serve.journal_steps(serve.journal_dir(t.dir)) == []
        print(f"   {name}: {expected[name]}/{expected[name]} windows "
              f"committed once, verify from bytes")
    print("OK: isolation held — one tenant's poison never cost the "
          "other a window")


if __name__ == "__main__":
    main()
