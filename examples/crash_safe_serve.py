"""Crash-safe proving service demo: kill the prover mid-run, restart it,
and watch every window get proved exactly once anyway.

The durability contract (see `launch/serve.py`) in action:

1. a `ProverService` journals every submitted step witness to disk
   BEFORE enqueueing it, and commits finished windows to an append-only
   ``MANIFEST.jsonl``;
2. a `FailureInjector` fault kills the service partway through the run
   — here at the nastiest point, AFTER a proof file is written but
   BEFORE its manifest commit (the classic double-write hazard);
3. a restarted service against the same out-dir replays the journal,
   re-proves every un-committed window, resumes training at
   ``service.next_step``, and the manifest audit shows exactly ONE
   ``COMMITTED`` line per window — verified from bytes via ``vk.bin``.

    PYTHONPATH=src python examples/crash_safe_serve.py \
        [--steps 6] [--window 2] [--out-dir /tmp/zkdl_crash_demo]
"""
import argparse
import os
import shutil

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--window", type=int, default=2)
    ap.add_argument("--widths", default="4,4,4")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--out-dir", default="/tmp/zkdl_crash_demo")
    args = ap.parse_args()

    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import build_fcnn_graph
    from repro.core.pipeline.proofio import decode_vk
    from repro.core.pipeline.verifier import verify_bytes
    from repro.launch import serve
    from repro.train.resilience import FailureInjector, SimulatedFailure

    shutil.rmtree(args.out_dir, ignore_errors=True)
    widths = tuple(int(w) for w in args.widths.split(","))
    quant = QuantConfig(q_bits=16, r_bits=4)
    graph = build_fcnn_graph(widths, batch=args.batch)
    wits = synthetic_sgd_trajectory_widths(args.steps, widths, args.batch,
                                           quant, seed=5)

    # -- run 1: the worker dies between proof write and manifest commit
    print("== run 1: fault armed at commit/pre-manifest ==")
    svc = serve.ProverService(
        graph, quant, n_steps=args.window, out_dir=args.out_dir,
        rng_seed=5, injector=FailureInjector.from_spec(
            "commit/pre-manifest@0"))
    svc.start(warm=True)
    crashed = False
    for wit in wits:
        try:
            svc.submit(wit)
        except (SimulatedFailure, RuntimeError) as exc:
            print(f"   training saw the prover die: {exc}")
            crashed = True
            break
    try:
        svc.close(timeout=300)
    except (SimulatedFailure, RuntimeError) as exc:
        crashed = True
        print(f"   close() surfaced the worker death: {exc}")
    assert crashed, "the injected fault never fired"
    journaled = serve.journal_steps(serve.journal_dir(args.out_dir))
    print(f"   journal retains steps {journaled}; manifest: "
          f"{ {w: r['status'] for w, r in serve.read_manifest(args.out_dir).items()} }")

    # -- run 2: restart against the same out-dir, no faults
    print("== run 2: restart, replay, resume ==")
    svc = serve.ProverService(graph, quant, n_steps=args.window,
                              out_dir=args.out_dir, rng_seed=5)
    svc.start(warm=True)
    print(f"   replayed {svc.stats['replayed']} journaled steps, "
          f"training resumes at step {svc.next_step}")
    for wit in wits[svc.next_step:]:
        svc.submit(wit)
    svc.close(timeout=300)

    # -- audit: every window committed exactly once, all proofs verify
    man = serve.read_manifest(args.out_dir)
    counts = serve.manifest_commit_counts(args.out_dir)
    with open(os.path.join(args.out_dir, "vk.bin"), "rb") as f:
        vk = decode_vk(f.read())
    n_windows = args.steps // args.window
    for w in range(n_windows):
        assert man[w]["status"] == "COMMITTED", (w, man.get(w))
        assert counts[w] == 1, f"window {w} committed {counts[w]} times"
        with open(os.path.join(args.out_dir, f"proof_{w:06d}.bin"),
                  "rb") as f:
            assert verify_bytes(vk, f.read(), label=b"zkdl/train"), w
        print(f"   window {w}: COMMITTED once, verifies from bytes")
    assert serve.journal_steps(serve.journal_dir(args.out_dir)) == []
    print(f"OK: {n_windows}/{n_windows} windows proved exactly once "
          f"across the crash")


if __name__ == "__main__":
    main()
