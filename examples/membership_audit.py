"""Data-copyright audit (Section 4.4 + Appendix B) on REAL proof bytes.

End-to-end `repro.audit` flow: a trainer proves two aggregation windows,
binds the per-sample commitments carried in each proof into a
sparse-Merkle dataset root (`DatasetBinding`), and a data owner audits
"were my committed samples used — and in which window?" purely from
serialized artifacts: the binding, the audit, and a window's proof
bytes.

    PYTHONPATH=src python examples/membership_audit.py [--hash sha256]
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hash", default="sha256",
                    choices=["md5", "sha1", "sha256"])
    ap.add_argument("--steps", type=int, default=2,
                    help="T: training steps aggregated per window")
    args = ap.parse_args()

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.audit import membership as mem
    from repro.core.pipeline import (build_fcnn_graph,
                                     compile as zk_compile, encode_proof,
                                     prove_session, verify_bytes)
    from repro.core.pipeline.tables import rand_scalar
    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)

    widths, batch, qc = (4, 4, 4), 2, QuantConfig(q_bits=16, r_bits=4)
    t0 = time.time()
    pk, vk = zk_compile(build_fcnn_graph(widths, batch=batch), qc,
                        n_steps=args.steps)
    print(f"[audit] compiled T={args.steps} window in {time.time()-t0:.1f}s")

    # the trainer proves two windows of a real SGD trajectory
    raws = []
    for w in range(2):
        wits = synthetic_sgd_trajectory_widths(args.steps, widths, batch,
                                               qc, seed=7 + w)
        t0 = time.time()
        raws.append(encode_proof(prove_session(
            pk, wits, np.random.default_rng(7 + w))))
        assert verify_bytes(vk, raws[w])
        print(f"[audit] window {w}: {len(raws[w])} B proof in "
              f"{time.time()-t0:.1f}s ({args.steps * batch} samples)")

    # ... and binds every window's sample commitments into ONE root
    t0 = time.time()
    tree, binding = mem.build_binding(
        {w: mem.sample_coms(raw) for w, raw in enumerate(raws)},
        hash_name=args.hash)
    print(f"[audit] dataset root {binding.root.hex()[:16]}... bound "
          f"({binding.n_samples} samples, {len(binding.to_bytes())} B "
          f"binding) in {(time.time()-t0)*1e3:.1f} ms")

    # the data owner queries: trained-on samples from both windows plus
    # held-out samples they committed but never handed to the trainer
    rng = np.random.default_rng(99)
    lim = 1 << (qc.q_bits - 1)
    held_out = [mem.com_to_bytes(mem.commit_sample(
        pk, rng.integers(-lim, lim, size=pk.keys.kx.n), rand_scalar(rng)))
        for _ in range(3)]
    queried = ([mem.com_to_bytes(c) for c in mem.sample_coms(raws[0])[:2]]
               + [mem.com_to_bytes(c)
                  for c in mem.sample_coms(raws[1])[:2]] + held_out)

    audit = mem.prove_membership(tree, binding, 0, queried)
    t0 = time.time()
    verdict = mem.verify_membership(
        mem.DatasetBinding.from_bytes(binding.to_bytes()),
        mem.MembershipAudit.from_bytes(audit.to_bytes()),
        proof_bytes=raws[0], vk=vk)
    dt = (time.time() - t0) * 1e3
    assert verdict.ok, verdict.reason
    print(f"[audit] owner verified from bytes in {dt:.1f} ms -> ACCEPT: "
          f"{verdict.n_members}/{len(queried)} in dataset, "
          f"{verdict.n_window_members} used in window 0 "
          f"(ground truth 4 / 2)")
    assert verdict.n_members == 4 and verdict.n_window_members == 2

    # the trainer cannot replay another window's proof for the claim
    replay = mem.verify_membership(binding, audit, proof_bytes=raws[1],
                                   vk=vk)
    assert not replay.ok
    print(f"[audit] cross-window replay rejected ({replay.reason})")

    # ... nor flip a membership answer
    h = mem.merkle.hash_bits(queried[0], args.hash)
    forged = mem.MembershipAudit.from_bytes(audit.to_bytes())
    forged.proof.included.remove(h)
    forged.proof.excluded.append(h)
    assert not mem.verify_membership(binding, forged,
                                     proof_bytes=raws[0], vk=vk).ok
    print("[audit] forged answer rejected (soundness check). done.")


if __name__ == "__main__":
    main()
