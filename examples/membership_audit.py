"""Data-copyright audit (Section 4.4 + Appendix B): a copyright owner
queries whether their data points were in the committed training set and
verifies the trainer's Merkle (non-)membership proofs.

    PYTHONPATH=src python examples/membership_audit.py [--n-data 5000]
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-data", type=int, default=2000)
    ap.add_argument("--n-query", type=int, default=20)
    ap.add_argument("--hash", default="sha256",
                    choices=["md5", "sha1", "sha256"])
    args = ap.parse_args()

    from repro.core import merkle

    rng = np.random.default_rng(0)
    # per-sample deterministic Pedersen commitments stand in as 32B digests
    dataset = [rng.bytes(32) for _ in range(args.n_data)]

    t0 = time.time()
    tree = merkle.MerkleTree(dataset, args.hash)
    print(f"[audit] trainer built Merkle tree over {args.n_data} committed "
          f"samples in {time.time()-t0:.1f}s (root published + endorsed)")

    # the copyright owner queries a mix: half in the set, half not
    owned_in = dataset[: args.n_query // 2]
    owned_out = [rng.bytes(32) for _ in range(args.n_query
                                              - args.n_query // 2)]
    queried = owned_in + owned_out

    t0 = time.time()
    proof = tree.prove_membership(queried)
    print(f"[audit] trainer answered {len(queried)} queries in "
          f"{(time.time()-t0)*1e3:.1f} ms; proof = {proof.size_nodes()} "
          f"hash values")

    t0 = time.time()
    ok = merkle.verify_membership(queried, tree.root, proof, args.hash)
    dt = (time.time() - t0) * 1e3
    print(f"[audit] owner verified in {dt:.2f} ms -> "
          f"{'ACCEPT' if ok else 'REJECT'}")
    assert ok
    print(f"[audit] members found: {len(proof.included)}, "
          f"non-members: {len(proof.excluded)} (ground truth "
          f"{len(owned_in)}/{len(owned_out)})")

    # the trainer cannot lie: flip one answer and the proof fails
    h = merkle.hash_bits(owned_in[0], args.hash)
    proof.included.remove(h)
    proof.excluded.append(h)
    assert not merkle.verify_membership(queried, tree.root, proof, args.hash)
    print("[audit] forged answer rejected (soundness check). done.")


if __name__ == "__main__":
    main()
