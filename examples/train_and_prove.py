"""End-to-end verifiable training: train a quantized FCNN for N steps,
streaming ONE aggregated proof per --agg-window batch updates (the
FAC4DNN cross-step aggregation), with checkpoint/restart.

This is the paper's deployment story in miniature, under the graph-first
lifecycle: `compile()` freezes the proof graph into a (pk, vk) pair
once; the trainer runs quantized SGD, queues each step's witness in a
`ProofSession(pk)`, and every window emits one proof SERIALIZED to
``proof_<step>.bin`` next to ``vk.bin`` — the trusted verifier (here: a
`verify_bytes` call against a vk re-read from disk, in real life: a
different machine) needs nothing else.  Interrupt and resume at any
window boundary from the checkpoint.

    PYTHONPATH=src python examples/train_and_prove.py \
        --steps 4 --width 16 --batch 8 [--agg-window 2] [--no-verify] \
        [--proof-dir /tmp/zkdl_proofs]

Scaling note: width 4096 x 16 layers (the paper's 200M-param experiment)
is the same code path; per-step proving cost on this CPU substrate is the
Table-2 column in EXPERIMENTS.md, divided by the aggregation window (see
BENCH_agg_steps.json for the amortization curve).
"""
import argparse
import os

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--lr-shift", type=int, default=10,
                    help="learning rate = 2^-shift (integer SGD)")
    ap.add_argument("--agg-window", type=int, default=2,
                    help="training steps aggregated into each proof")
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/zkdl_train_ckpt.npz")
    ap.add_argument("--proof-dir", default="/tmp/zkdl_proofs",
                    help="where vk.bin and per-window proof_<step>.bin land")
    args = ap.parse_args()

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.core import quantfc
    from repro.core.quantfc import QuantConfig, train_step_witness
    from repro.core.pipeline import (VerifyingKey, build_fcnn_graph,
                                     compile, encode_proof, verify_bytes)
    from repro.launch.steps import ZkdlProveHook

    qc = QuantConfig(q_bits=16, r_bits=8)
    window = max(1, args.agg_window)
    # session label = the public transcript domain separator; the
    # verifier must bind to the same one or (correctly) reject
    label = b"zkdl/train"
    graph = build_fcnn_graph((args.width,) * (args.layers + 1), args.batch)
    pk, vk = compile(graph, qc, n_steps=window)
    os.makedirs(args.proof_dir, exist_ok=True)
    vk_path = os.path.join(args.proof_dir, "vk.bin")
    with open(vk_path, "wb") as f:
        f.write(vk.to_bytes())
    rng = np.random.default_rng(0)

    # synthetic dataset (fixed): batches cycle deterministically
    data_x = rng.uniform(-1, 1, (args.batch * 8, args.width))
    data_y = rng.uniform(-1, 1, (args.batch * 8, args.width))

    # restore or init weights (checkpoints land on window boundaries, so
    # a resumed run never re-proves a half-aggregated window)
    start = 0
    if os.path.exists(args.ckpt):
        with np.load(args.ckpt) as z:
            ws = [z[f"w{i}"] for i in range(args.layers)]
            start = int(z["step"])
        print(f"[train] resumed from {args.ckpt} at step {start}")
    else:
        ws = [quantfc.quantize(
            rng.uniform(-1, 1, (args.width, args.width)) * 0.3, qc)
            for _ in range(args.layers)]

    # the hook owns the session window: every `window` observed steps it
    # proves one aggregated transcript; the callback serializes it,
    # verifies FROM BYTES against the on-disk vk (the deployment
    # contract), then checkpoints on the window boundary
    def on_proof(step, proof, tp):
        raw = encode_proof(proof)
        pf = os.path.join(args.proof_dir, f"proof_{step:06d}.bin")
        with open(pf, "wb") as f:
            f.write(raw)
        verdict = ""
        if not args.no_verify:
            with open(vk_path, "rb") as f:
                vk_disk = VerifyingKey.from_bytes(f.read())
            ok = verify_bytes(vk_disk, raw, label=label)
            if not ok:
                raise RuntimeError(f"serialized proof REJECTED at {step}")
            verdict = ", verified-from-bytes"
        print(f"[train] step {step}: aggregated proof over "
              f"{proof.n_steps} steps -> {pf} ({len(raw)/1024:.1f} kB"
              f" in {tp:.1f}s, {tp/proof.n_steps:.1f}s/step{verdict})",
              flush=True)
        np.savez(args.ckpt, step=step + 1,
                 **{f"w{i}": ws[i] for i in range(args.layers)})

    # the hook's in-process verify is redundant with the from-bytes
    # check above, so switch it off
    hook = ZkdlProveHook(pk, rng, verify=False, on_proof=on_proof,
                         label=label)
    for step in range(start, args.steps):
        lo = (step * args.batch) % data_x.shape[0]
        xb = quantfc.quantize(data_x[lo:lo + args.batch], qc)
        yb = quantfc.quantize(data_y[lo:lo + args.batch], qc)
        wit = train_step_witness(xb, yb, ws, qc)

        # integer SGD on the (about-to-be-)PROVEN gradients
        ws = quantfc.sgd_apply(ws, wit.gw, args.lr_shift, qc)
        hook.observe(step, wit)

    done = args.steps - start
    n_proofs = len(hook.proofs)
    print(f"[train] {done} steps done; {n_proofs} aggregated proofs in "
          f"{args.proof_dir} (window {window}); checkpoint at {args.ckpt}")
    if hook.n_pending:
        # checkpoints land on window boundaries only: the trailing
        # partial window is UNPROVEN and not persisted -- a resumed run
        # recomputes those steps deterministically and proves them with
        # the next full window.
        print(f"[train] WARNING: {hook.n_pending} trailing step(s) "
              f"form a partial window -- unproven and not checkpointed; "
              f"they will be re-run (and proven) on resume, or pick "
              f"--steps as a multiple of --agg-window", flush=True)


if __name__ == "__main__":
    main()
