"""End-to-end verifiable training: train a quantized FCNN for N steps,
producing a Protocol-2 proof per batch update, with checkpoint/restart.

This is the paper's deployment story in miniature: the trainer runs
quantized SGD and streams (commitments, proof) per step to the trusted
verifier; interrupt and resume at any step from the checkpoint.

    PYTHONPATH=src python examples/train_and_prove.py \
        --steps 5 --width 16 --batch 8 [--prove-every 1] [--no-verify]

Scaling note: width 4096 x 16 layers (the paper's 200M-param experiment)
is the same code path; per-step proving cost on this CPU substrate is the
Table-2 column in EXPERIMENTS.md.
"""
import argparse
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--lr-shift", type=int, default=10,
                    help="learning rate = 2^-shift (integer SGD)")
    ap.add_argument("--prove-every", type=int, default=1)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/zkdl_train_ckpt.npz")
    args = ap.parse_args()

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.core import quantfc, zkdl
    from repro.core.quantfc import QuantConfig, train_step_witness

    qc = QuantConfig(q_bits=16, r_bits=8)
    cfg = zkdl.ZkdlConfig(n_layers=args.layers, batch=args.batch,
                          width=args.width, q_bits=16, r_bits=8)
    keys = zkdl.make_keys(cfg)
    rng = np.random.default_rng(0)

    # synthetic dataset (fixed): batches cycle deterministically
    data_x = rng.uniform(-1, 1, (args.batch * 8, args.width))
    data_y = rng.uniform(-1, 1, (args.batch * 8, args.width))

    # restore or init weights
    start = 0
    if os.path.exists(args.ckpt):
        with np.load(args.ckpt) as z:
            ws = [z[f"w{i}"] for i in range(args.layers)]
            start = int(z["step"])
        print(f"[train] resumed from {args.ckpt} at step {start}")
    else:
        ws = [quantfc.quantize(
            rng.uniform(-1, 1, (args.width, args.width)) * 0.3, qc)
            for _ in range(args.layers)]

    proof_sizes = []
    for step in range(start, args.steps):
        lo = (step * args.batch) % data_x.shape[0]
        xb = quantfc.quantize(data_x[lo:lo + args.batch], qc)
        yb = quantfc.quantize(data_y[lo:lo + args.batch], qc)
        wit = train_step_witness(xb, yb, ws, qc)

        if step % args.prove_every == 0:
            t0 = time.time()
            proof = zkdl.prove_step(keys, wit, rng)
            tp = time.time() - t0
            proof_sizes.append(proof.size_bytes())
            if not args.no_verify:
                assert zkdl.verify_step(keys, proof), "verifier rejected!"
            print(f"[train] step {step}: proof {proof.size_bytes()/1024:.1f} kB"
                  f" in {tp:.1f}s (verified={not args.no_verify})", flush=True)

        # integer SGD on the PROVEN gradients (scale 2^{2R} -> 2^R shift)
        for i in range(args.layers):
            ws[i] = ws[i] - (wit.gw[i] >> (qc.r_bits + args.lr_shift))
            lim = 1 << (qc.q_bits - 1)
            ws[i] = np.clip(ws[i], -lim, lim - 1)
        np.savez(args.ckpt, step=step + 1,
                 **{f"w{i}": ws[i] for i in range(args.layers)})

    print(f"[train] {args.steps - start} steps done; mean proof "
          f"{np.mean(proof_sizes)/1024:.1f} kB; checkpoint at {args.ckpt}")


if __name__ == "__main__":
    main()
